"""Machine-readable benchmark output: ``BENCH_<name>.json`` files.

Every ``benchmarks/bench_*.py`` writes one JSON at the repo root with
its rows, the config that produced them, the git sha, and a flat
``metrics`` dict of key scalars. ``benchmarks/run.py`` aggregates the
per-bench files into ``BENCH_summary.json``; CI uploads all of them as
workflow artifacts and ``benchmarks/compare.py`` gates the metrics
against the committed ``benchmarks/baselines.json``.

Gated metrics are HIGHER-IS-BETTER by convention (ratios, throughputs,
break-even points); store the inverse of anything lower-is-better.
"""
from __future__ import annotations

import json
import subprocess
from pathlib import Path
from typing import Dict, List, Optional, Sequence

REPO_ROOT = Path(__file__).resolve().parent.parent


def git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT, check=True,
            capture_output=True, text=True).stdout.strip()
    except Exception:
        return "unknown"


def write_bench_json(name: str, *, rows: Sequence[Sequence],
                     config: Dict, metrics: Dict[str, float],
                     header: Optional[List[str]] = None,
                     out_dir: Optional[Path] = None) -> Path:
    """Write ``BENCH_<name>.json`` at the repo root. Returns the path."""
    out_dir = Path(out_dir) if out_dir is not None else REPO_ROOT
    path = out_dir / f"BENCH_{name}.json"
    doc = {
        "name": name,
        "git_sha": git_sha(),
        "config": config,
        "header": header,
        "rows": [list(r) for r in rows],
        "metrics": {k: float(v) for k, v in metrics.items()},
    }
    path.write_text(json.dumps(doc, indent=2, default=str) + "\n")
    return path


def collect_bench_jsons(out_dir: Optional[Path] = None) -> Dict[str, Dict]:
    """All BENCH_*.json currently at the repo root, keyed by bench name
    (the aggregate summary file itself is excluded)."""
    out_dir = Path(out_dir) if out_dir is not None else REPO_ROOT
    out = {}
    for p in sorted(out_dir.glob("BENCH_*.json")):
        if p.name == "BENCH_summary.json":
            continue
        try:
            doc = json.loads(p.read_text())
        except json.JSONDecodeError:
            continue
        out[doc.get("name", p.stem[len("BENCH_"):])] = doc
    return out
