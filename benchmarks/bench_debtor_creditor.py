"""Paper Fig. 7(a-c): debtor / creditor / aggregate TPS vs blocks moved.

Reproduces the shape of the paper's micro-benchmark with the calibrated
Eq. 5-7 model: debtor runs a 1000K-token context, creditor runs
~500-token traffic; KV blocks migrate debtor -> creditor.

Heavy-tail scenario (striped Algorithm 1): a debtor whose movable
prefix exceeds ANY single creditor's free blocks, planned by the
single-creditor and the striped planner — modeled aggregate TPS via the
GreedyScheduler's own Eq. 5-7 search, measured aggregate throughput via
the event-driven simulator on a heavy-tail trace (1-in-8 requests at
1.2-1.8M tokens, beyond single-destination feasibility). The striped
planner must win both.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config
from repro.serving.perfmodel import InstancePerfModel
from repro.serving.scheduler import GreedyScheduler, InstanceView
from repro.serving.simulator import ClusterSimulator, SimRequest

try:
    from benchmarks.benchjson import write_bench_json
except ImportError:                      # run as a script from benchmarks/
    from benchjson import write_bench_json

BLOCK_TOKENS = 512


def run(csv=True):
    cfg = get_config("mistral-nemo-12b")
    m = InstancePerfModel(cfg, chips=8)      # one "instance" = 8 chips
    long_len = 1_000_000
    spare = 400_000
    rows = []
    for blocks in range(0, 1_000_000 // BLOCK_TOKENS + 1,
                        50_000 // BLOCK_TOKENS):
        off = blocks * BLOCK_TOKENS
        extra = min(off // 2_000, 240)
        debtor = m.tps(1 + extra, [long_len] + [500] * extra,
                       offloaded_tokens=off)
        c_beta = max(8, 128 - max(0, off - spare) // 5_000)
        creditor = m.tps(c_beta, [5_000] * c_beta, hosted_tokens=off)
        rows.append((blocks, debtor, creditor, debtor + creditor))
    if csv:
        print("fig7_blocks_moved,debtor_tps,creditor_tps,aggregate_tps")
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]:.1f},{r[3]:.1f}")
    return rows


# ------------------------------------------------------------------ #
# Heavy tail: striped planner vs single-creditor planner
# ------------------------------------------------------------------ #
def _heavy_tail_views(bs=BLOCK_TOKENS, nblk=2200, n_creditors=4,
                      creditor_free=100):
    """One debtor owning a 1M-token request on a nearly-full pool; N
    creditors whose free blocks are each far below the movable prefix."""
    debtor = InstanceView(
        inst_id=0, batch_size=2, mem_blocks_total=nblk,
        mem_blocks_used=nblk - 50,
        requests={7: (bs * 2000, 2000, True), 8: (bs * 150, 150, True)})
    creditors = [InstanceView(
        inst_id=i + 1, batch_size=16, mem_blocks_total=nblk,
        mem_blocks_used=nblk - creditor_free,
        requests={100 + i: (bs * 16, 16, True)})
        for i in range(n_creditors)]
    return [debtor] + creditors


def _heavy_tail_trace(n=64, seed=0):
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(0.6))
        if i % 8 == 0:                   # 1-in-8 beyond single-creditor
            plen = int(rng.uniform(1.2e6, 1.8e6))
            out = 256
        else:
            plen = int(rng.lognormal(7.0, 1.0)) + 64
            out = int(rng.integers(64, 256))
        reqs.append(SimRequest(req_id=i, arrival=t, prompt_len=plen,
                               output_len=out))
    return reqs


def run_heavy_tail(csv=True):
    cfg = get_config("mistral-nemo-12b")
    perf = InstancePerfModel(cfg, chips=8)
    rows = []
    # Modeled: the planner's own Eq. 5-7 objective on the same views,
    # scored via the scheduler's public modeled_aggregate_tps.
    modeled = {}
    for label, stripes in (("single", 1), ("striped", 8)):
        sched = GreedyScheduler(perf, block_size=BLOCK_TOKENS,
                                beta_thres=8, mem_util_thres=0.96,
                                max_stripes=stripes)
        views = _heavy_tail_views()
        plan = sched.plan(views)
        legs = max((len(m.legs) for m in plan), default=0)
        moved = sum(m.num_blocks for m in plan)
        modeled[label] = sched.modeled_aggregate_tps(views, plan)
        rows.append((f"modeled_{label}", legs, moved, modeled[label],
                     0, 0))
    # Measured: the event-driven simulator on a heavy-tail trace.
    measured = {}
    for label, striped in (("single", False), ("striped", True)):
        sim = ClusterSimulator(cfg, policy="infinite", n_instances=4,
                               chips_per_instance=8, striped=striped)
        r = sim.run(_heavy_tail_trace(), horizon=500.0)
        measured[label] = r["throughput_tok_s"]
        rows.append((f"measured_{label}", sim.max_stripes, 0,
                     r["throughput_tok_s"], r["finished"], r["failed"]))
    if csv:
        print("fig7_heavytail_case,max_legs,blocks_moved,aggregate_tps,"
              "finished,failed")
        for r in rows:
            print(f"{r[0]},{r[1]},{r[2]},{r[3]:.1f},{r[4]},{r[5]}")
    gains = (modeled["striped"] / modeled["single"],
             measured["striped"] / measured["single"])
    return rows, gains


def main():
    t0 = time.perf_counter()
    rows = run()
    ht_rows, (g_model, g_meas) = run_heavy_tail()
    us = (time.perf_counter() - t0) * 1e6
    base = rows[0][3]
    peak = max(r[3] for r in rows)
    peak_blocks = max(rows, key=lambda r: r[3])[0]
    print(f"bench_debtor_creditor,{us:.1f},peak_gain={peak / base:.2f}x"
          f"@blocks={peak_blocks},striped_modeled={g_model:.2f}x,"
          f"striped_measured={g_meas:.2f}x")
    write_bench_json(
        "debtor_creditor",
        rows=[list(r) for r in rows] + [list(r) for r in ht_rows],
        config={"model": "mistral-nemo-12b", "chips": 8,
                "block_tokens": BLOCK_TOKENS,
                "heavy_tail": {"n": 64, "heavy_every": 8,
                               "heavy_len": [1.2e6, 1.8e6]}},
        header=["fig7_blocks_or_case", "debtor_or_legs",
                "creditor_or_blocks", "aggregate_tps", "finished",
                "failed"],
        metrics={"peak_gain": peak / base,
                 "striped_over_single_modeled": g_model,
                 "striped_over_single_measured": g_meas})


if __name__ == "__main__":
    main()
