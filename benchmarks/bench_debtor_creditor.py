"""Paper Fig. 7(a-c): debtor / creditor / aggregate TPS vs blocks moved.

Reproduces the shape of the paper's micro-benchmark with the calibrated
Eq. 5-7 model: debtor runs a 1000K-token context, creditor runs
~500-token traffic; KV blocks migrate debtor -> creditor.
"""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.serving.perfmodel import InstancePerfModel

BLOCK_TOKENS = 512


def run(csv=True):
    cfg = get_config("mistral-nemo-12b")
    m = InstancePerfModel(cfg, chips=8)      # one "instance" = 8 chips
    long_len = 1_000_000
    spare = 400_000
    rows = []
    for blocks in range(0, 1_000_000 // BLOCK_TOKENS + 1,
                        50_000 // BLOCK_TOKENS):
        off = blocks * BLOCK_TOKENS
        extra = min(off // 2_000, 240)
        debtor = m.tps(1 + extra, [long_len] + [500] * extra,
                       offloaded_tokens=off)
        c_beta = max(8, 128 - max(0, off - spare) // 5_000)
        creditor = m.tps(c_beta, [5_000] * c_beta, hosted_tokens=off)
        rows.append((blocks, debtor, creditor, debtor + creditor))
    if csv:
        print("fig7_blocks_moved,debtor_tps,creditor_tps,aggregate_tps")
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]:.1f},{r[3]:.1f}")
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6
    base = rows[0][3]
    peak = max(r[3] for r in rows)
    peak_blocks = max(rows, key=lambda r: r[3])[0]
    print(f"bench_debtor_creditor,{us:.1f},peak_gain={peak / base:.2f}x"
          f"@blocks={peak_blocks}")


if __name__ == "__main__":
    main()
