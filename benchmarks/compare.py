"""CI benchmark gate: fail on >20% regression vs committed baselines.

Usage: PYTHONPATH=src python benchmarks/compare.py [--tolerance 0.2]
           [--strict]

Reads every ``BENCH_<name>.json`` at the repo root (produced by the
benchmarks that just ran) and compares each metric listed in
``benchmarks/baselines.json`` against its committed baseline value.
Metrics are HIGHER-IS-BETTER by convention (store the inverse of
anything lower-is-better); a metric that dropped below
``(1 - tolerance) * baseline`` fails the gate. By default only benches
whose JSON is present are compared (the fast PR job runs a smoke
subset); ``--strict`` (the nightly full sweep) additionally fails on
any baselined bench whose JSON is missing. Metrics present in the
fresh JSONs but absent from the baselines are reported as
informational only, so adding a new benchmark never breaks CI until
its baseline is committed.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

try:
    from benchmarks.benchjson import collect_bench_jsons
except ImportError:                      # run as a script from benchmarks/
    from benchjson import collect_bench_jsons

BASELINES = Path(__file__).resolve().parent / "baselines.json"


def compare(tolerance: float = 0.2, strict: bool = False) -> int:
    baselines = json.loads(BASELINES.read_text())
    fresh = collect_bench_jsons()
    failures = []
    compared = 0
    for bench, metrics in sorted(baselines.items()):
        doc = fresh.get(bench)
        if doc is None:
            if strict:
                failures.append(f"{bench}: BENCH_{bench}.json missing "
                                f"(benchmark did not run?)")
            else:
                print(f"{'SKIPPED':10s} {bench}: no fresh JSON "
                      f"(not part of this run)")
            continue
        compared += 1
        got = doc.get("metrics", {})
        for key, base in sorted(metrics.items()):
            if key not in got:
                failures.append(f"{bench}.{key}: metric missing")
                continue
            new = got[key]
            floor = (1.0 - tolerance) * base
            status = "OK" if new >= floor else "REGRESSION"
            print(f"{status:10s} {bench}.{key}: {new:.4g} "
                  f"(baseline {base:.4g}, floor {floor:.4g})")
            if new < floor:
                failures.append(
                    f"{bench}.{key}: {new:.4g} < {floor:.4g} "
                    f"(>{tolerance:.0%} regression vs {base:.4g})")
    # Informational: fresh metrics without a committed baseline.
    for bench, doc in sorted(fresh.items()):
        for key, val in sorted(doc.get("metrics", {}).items()):
            if key not in baselines.get(bench, {}):
                print(f"{'NEW':10s} {bench}.{key}: {val:.4g} "
                      f"(no baseline committed)")
    if compared == 0:
        failures.append("no baselined bench produced a JSON — nothing "
                        "was gated")
    if failures:
        print("\nBenchmark gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nBenchmark gate passed ({compared} benches).")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional drop vs baseline")
    ap.add_argument("--strict", action="store_true",
                    help="fail if any baselined bench JSON is missing "
                         "(nightly full sweep)")
    args = ap.parse_args()
    sys.exit(compare(args.tolerance, strict=args.strict))


if __name__ == "__main__":
    main()
