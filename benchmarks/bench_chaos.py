"""Chaos benchmark (ISSUE 9 acceptance gates).

Three measured sections on a real smoke-scale cluster, all with fault
injection live:

  * recovery token identity — a spanning request is decoding with its
    KV striped onto a creditor rank; the creditor is killed mid-decode
    and the request is re-admitted via token replay (re-prefill of
    prompt + emitted output, no resampling). The final stream must be
    byte-identical to an unfailed dense oracle, in BOTH per-instance
    and global-pool modes (gated as ``recovery_token_identity``).
  * goodput under one crash — a bursty deadline-carrying trace is
    served fault-free, then twice more with a planned ``FaultPlan``
    crash of a different rank mid-trace. The WORST crashed run's
    on-time finishes must stay >= 0.7x the fault-free run's (gated as
    ``chaos_goodput_ok``) — losing one of three ranks costs capacity
    and replay work but must not collapse service.
  * zero leaks — after every run (including the crashed ones) all
    allocators, quarantined ranks included, must drain to zero used
    blocks / zero reservations / zero request records (``zero_leak``;
    the benchmark raises on any leak).

Deadlines are calibrated against the measured decode step time so the
gate tracks recovery behavior, not machine speed. The whole benchmark
runs in float32: token identity across a changed KV placement is only
argmax-stable when the LSE-merge regrouping rounding is far below the
logit gaps (same convention as tests/test_faults.py).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import decode_step, init_params
from repro.models.prefill import prefill
from repro.serving import (Cluster, LLMServer, Request, RequestState,
                           SamplingParams, ServingConfig)
from repro.serving.config import FaultPolicy
from repro.serving.faults import FaultEvent, FaultPlan

try:
    from benchmarks.benchjson import write_bench_json
    from benchmarks.traces import gen_bursty_trace, overload_arrivals
except ImportError:                      # run as a script from benchmarks/
    from benchjson import write_bench_json
    from traces import gen_bursty_trace, overload_arrivals

N_REQ = 10               # bursty trace length (CI-smoke sized)
GEN_TOKENS = 8           # decode length per traced request
PROMPT_LEN = 12
CRASH_STEP = 6           # planned crash, steps after the warm-up drain
N_INSTANCES = 3


def _chaos_serving(**over) -> ServingConfig:
    base = dict(n_instances=N_INSTANCES, max_batch=2,
                heartbeat_timeout=0.0,
                faults=FaultPolicy(max_transfer_retries=2))
    base.update(over)
    return ServingConfig.smoke(**base)


def _assert_no_leaks(cl) -> None:
    """Every allocator (quarantined ranks included) fully drained."""
    for _ in range(2):                   # flush pending hosted releases
        cl.step()
    for i, e in cl.engines.items():
        a = e.rmanager.pool.alloc
        if a.used_count or a.reserved or e.rmanager.pool.requests:
            raise AssertionError(
                f"inst {i} leaked: used={a.used_count} "
                f"reserved={a.reserved} "
                f"records={len(e.rmanager.pool.requests)}")


def _greedy_reference(params, cfg, prompt, n_new):
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
    return out


def run_identity(params, cfg, global_pool, csv=True):
    """Kill the creditor hosting a spanning request's KV mid-decode and
    diff the replayed request against an unfailed dense oracle."""
    rng = np.random.default_rng(23)
    prompt = rng.integers(0, cfg.vocab_size, 40).tolist()
    n_new = 12
    ref = _greedy_reference(params, cfg, prompt, n_new)

    cl = Cluster(params, cfg,
                 _chaos_serving(pool_blocks=32, global_pool=global_pool))
    req = Request(prompt=prompt,
                  sampling=SamplingParams(max_new_tokens=n_new))
    cl.submit(req)
    for _ in range(30):
        cl.step()
        if len(req.output) >= 4:
            break
    creditors = [i for i, e in cl.engines.items()
                 if e.rmanager.is_hosting(req.req_id)]
    assert creditors, "identity scenario produced no hosted span"
    cl.kill_instance(creditors[0])
    cl.run_until_done(max_steps=300)
    _assert_no_leaks(cl)

    identical = (req.state == RequestState.FINISHED
                 and req.output == ref and req.replays == 1
                 and cl.fault_stats.recoveries == 1)
    mode = "global" if global_pool else "local"
    if csv:
        print(f"identity_{mode},replays={req.replays},"
              f"replayed_tokens={cl.fault_stats.replayed_tokens},"
              f"identical={int(identical)}")
    return float(identical)


def _calibrate_step_s(params, cfg) -> float:
    """Measured per-step wall time of a warm 2-slot decode."""
    srv = LLMServer(params, cfg, ServingConfig.smoke(
        n_instances=1, max_batch=2, heartbeat_timeout=0.0))
    rng = np.random.default_rng(7)
    for _ in range(2):
        srv.submit(rng.integers(0, cfg.vocab_size, PROMPT_LEN).tolist(),
                   SamplingParams(max_new_tokens=24))
    srv.step()                           # pays compile
    t0 = time.perf_counter()
    n = 12
    for _ in range(n):
        srv.step()
    dt = (time.perf_counter() - t0) / n
    srv.drain()
    return dt


def run_goodput(params, cfg, csv=True):
    """Deadline goodput of the same bursty trace, fault-free vs with
    one planned rank crash mid-trace (two different victims)."""
    step_s = _calibrate_step_s(params, cfg)
    # At-capacity arrival rate for N_INSTANCES * max_batch slots, each
    # holding a request for ~GEN_TOKENS steps.
    rate = (N_INSTANCES * 2) / (GEN_TOKENS * step_s)
    trace = gen_bursty_trace(N_REQ, rate, burst_factor=3.0,
                             prompt_len=PROMPT_LEN, seed=13)
    # Generous deadline: every request meets it fault-free; only the
    # crash (lost capacity + token replay) can push finishes past it.
    deadline_s = 80 * step_s

    def materialize():
        arrivals, _ = overload_arrivals(trace, cfg.vocab_size,
                                        deadline_p=1.0,
                                        deadline_s=deadline_s, seed=13)
        for a in arrivals:
            a.sampling = SamplingParams(max_new_tokens=GEN_TOKENS)
        return arrivals

    def serve(victim):
        srv = LLMServer(params, cfg, _chaos_serving())
        # Warm the compile cache outside the measured trace.
        srv.submit([1] * PROMPT_LEN,
                   SamplingParams(max_new_tokens=2)).result()
        if victim is not None:
            plan = FaultPlan(events=(FaultEvent(
                step=srv.cluster._step_count + CRASH_STEP,
                kind="crash", target=victim),))
            srv.cluster.install_faults(plan)
        stats = srv.run(materialize())
        stats["dead"] = srv.metrics["dead_instances"]
        stats["recoveries"] = srv.metrics["fault_recoveries"]
        _assert_no_leaks(srv.cluster)
        return stats

    base = serve(None)
    crashed = [serve(v) for v in (1, 2)]
    n = base["n_requests"]
    good_base = base["deadline_goodput"] * n
    good_worst = min(c["deadline_goodput"] * n for c in crashed)
    ratio = good_worst / max(good_base, 1.0)
    if csv:
        print("goodput_metric,fault_free,crash_v1,crash_v2")
        for k in ("deadline_goodput", "finished", "deadline_missed",
                  "dead", "recoveries", "throughput_tok_s"):
            print(f"{k},{base[k]:.3f},{crashed[0][k]:.3f},"
                  f"{crashed[1][k]:.3f}")
        print(f"step_s,{step_s * 1e3:.2f}ms,,")
        print(f"chaos_goodput_ratio,{ratio:.2f},,")
    return dict(ratio=ratio, step_s=step_s, base=base, crashed=crashed)


def main():
    t0 = time.perf_counter()
    cfg = dataclasses.replace(get_smoke_config("olmo-1b"),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    ident_local = run_identity(params, cfg, global_pool=False)
    ident_global = run_identity(params, cfg, global_pool=True)
    identity = ident_local * ident_global
    gp = run_goodput(params, cfg)
    us = (time.perf_counter() - t0) * 1e6
    print(f"bench_chaos,{us:.1f},identity={identity:.0f},"
          f"goodput_ratio={gp['ratio']:.2f}x")
    write_bench_json(
        "chaos",
        rows=[["identity", ident_local, ident_global, identity, 0.0],
              ["goodput", gp["base"]["deadline_goodput"],
               gp["crashed"][0]["deadline_goodput"],
               gp["crashed"][1]["deadline_goodput"], gp["ratio"]]],
        config={"model": "olmo-1b-smoke-f32", "n_req": N_REQ,
                "gen_tokens": GEN_TOKENS, "n_instances": N_INSTANCES,
                "crash_step": CRASH_STEP, "step_s": gp["step_s"]},
        header=["section", "a", "b", "c", "d"],
        metrics={
            # All gated metrics are higher-is-better.
            "recovery_token_identity": identity,
            "chaos_goodput_ratio": gp["ratio"],
            # Hard gate on the >= 0.7x acceptance bound.
            "chaos_goodput_ok": float(gp["ratio"] >= 0.7),
            # _assert_no_leaks raised already if this were false.
            "zero_leak": 1.0,
        })


if __name__ == "__main__":
    main()
