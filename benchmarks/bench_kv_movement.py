"""Paper Fig. 12: decode-throughput overhead of KV movement.

(a) REAL in-process cluster: a spanning request keeps moving KV chunks
    of m tokens/step (m in {0, 8, 16, 32}); wall-clock tokens/s measured
    on CPU at smoke scale — shows relative overhead of movement.
(b) Modeled on v5e: movement bytes/step vs decode-step time; overlap
    hides movement while move_bytes/ici_bw < step_time (the paper's
    16-tokens/step break-even).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.distributed.hardware import V5E
from repro.models.model import init_params
from repro.serving import Cluster, Request, SamplingParams
from repro.serving.perfmodel import InstancePerfModel

try:
    from benchmarks.benchjson import write_bench_json
except ImportError:                      # run as a script from benchmarks/
    from benchjson import write_bench_json


def modeled(csv=True):
    cfg = get_config("mistral-nemo-12b")
    perf = InstancePerfModel(cfg, chips=8)
    beta = 64
    step_t = cfg.num_layers * perf.t_layer(beta, [4096] * beta)
    rows = []
    for m_tokens in (0, 8, 16, 32, 64, 128):
        move_bytes = m_tokens * cfg.kv_bytes_per_token()
        t_move = move_bytes / V5E.ici_link_bw
        overlapped = max(step_t, t_move)          # overlap w/ compute
        serial = step_t + t_move                  # no overlap
        rows.append((m_tokens, step_t * 1e3, t_move * 1e3,
                     beta / overlapped, beta / serial))
    if csv:
        print("fig12_tokens_per_step,step_ms,move_ms,tps_overlap,"
              "tps_serial")
        for r in rows:
            print(f"{r[0]},{r[1]:.3f},{r[2]:.3f},{r[3]:.0f},{r[4]:.0f}")
    return rows


def measured(csv=True):
    """Paged-path cluster: KV lives in the block pools; the host-side
    work per decode step is only table/metadata assembly, reported as
    ``host_gather_us_per_step`` next to the bytes the moves copied."""
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    rows = []
    for chunk in (8, 16, 32):
        cl = Cluster(params, cfg, n_instances=2, max_batch=2,
                     max_local_len=48, pool_blocks=64, block_size=8,
                     move_chunk_tokens=chunk, schedule_every=1000)
        req = Request(prompt=list(rng.integers(0, cfg.vocab_size, 40)),
                      sampling=SamplingParams(max_new_tokens=24))
        cl.submit(req)
        t0 = time.perf_counter()
        cl.run_until_done(max_steps=300)
        dt = time.perf_counter() - t0
        moved = cl.throughput_stats["kv_moved_bytes"]
        steps = sum(e.stats.decode_steps for e in cl.engines.values())
        gather_us = sum(e.stats.host_gather_s
                        for e in cl.engines.values()) / max(steps, 1) * 1e6
        rows.append((chunk, len(req.output) / dt, moved, gather_us))
    if csv:
        print("fig12_measured_chunk,tok_per_s_cpu,kv_moved_bytes,"
              "host_gather_us_per_step")
        for r in rows:
            print(f"{r[0]},{r[1]:.2f},{r[2]},{r[3]:.1f}")
    return rows


def main():
    t0 = time.perf_counter()
    rows = modeled()
    mrows = measured()
    us = (time.perf_counter() - t0) * 1e6
    # break-even: largest m with overlapped == no-move throughput
    base = rows[0][3]
    be = max((r[0] for r in rows if r[3] >= base * 0.995), default=0)
    print(f"bench_kv_movement,{us:.1f},overlap_breakeven_tokens={be}")
    write_bench_json(
        "kv_movement",
        rows=[list(r) for r in rows] + [list(r) for r in mrows],
        config={"model_modeled": "mistral-nemo-12b", "chips": 8,
                "model_measured": "olmo-1b-smoke"},
        header=["tokens_per_step_or_chunk", "step_ms_or_tps",
                "move_ms_or_moved_bytes", "tps_overlap_or_gather_us",
                "tps_serial"],
        metrics={"overlap_breakeven_tokens": be})


if __name__ == "__main__":
    main()
