"""Paper Fig. 12: decode-throughput overhead of KV movement.

(a) Modeled on v5e: movement bytes/step vs decode-step time; overlap
    hides movement while move_bytes/ici_bw < step_time (the paper's
    16-tokens/step break-even; 128 at this model/batch point).
(b) MEASURED on the real in-process cluster: the same movement-heavy
    workload runs twice per chunk size — ``async_movement=False`` (the
    serial baseline: every pool-row copy chain is block_until_ready-ed
    at dispatch) vs ``True`` (the double-buffered staging layer keeps
    copies in flight behind decode compute) — plus a no-movement
    reference run. ``tps_overlap_on/off`` are wall-clock tokens/s;
    the measured break-even is the largest chunk whose OVERLAPPED
    throughput stays within 10% of the no-movement reference, the
    empirical analog of the modeled figure. The same runs also gate the
    donation hot path: ``decode_pool_zero_copy`` is the fraction of
    decode steps that did NOT copy the [L, NB, bs, K, hd] pool tensor
    (1.0 = every step updated the donated buffer in place).
"""
from __future__ import annotations

import gc
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.distributed.hardware import V5E
from repro.models.model import init_params
from repro.serving import LLMServer, SamplingParams, ServingConfig
from repro.serving.perfmodel import InstancePerfModel

try:
    from benchmarks.benchjson import write_bench_json
except ImportError:                      # run as a script from benchmarks/
    from benchjson import write_bench_json


def modeled(csv=True):
    cfg = get_config("mistral-nemo-12b")
    perf = InstancePerfModel(cfg, chips=8)
    beta = 64
    step_t = cfg.num_layers * perf.t_layer(beta, [4096] * beta)
    rows = []
    for m_tokens in (0, 8, 16, 32, 64, 128):
        move_bytes = m_tokens * cfg.kv_bytes_per_token()
        t_move = move_bytes / V5E.ici_link_bw
        overlapped = max(step_t, t_move)          # overlap w/ compute
        serial = step_t + t_move                  # no overlap
        rows.append((m_tokens, step_t * 1e3, t_move * 1e3,
                     beta / overlapped, beta / serial))
    if csv:
        print("fig12_tokens_per_step,step_ms,move_ms,tps_overlap,"
              "tps_serial")
        for r in rows:
            print(f"{r[0]},{r[1]:.3f},{r[2]:.3f},{r[3]:.0f},{r[4]:.0f}")
    return rows


def _run_cluster(params, cfg, *, move_chunk, async_movement,
                 max_local_len=48, n_new=32):
    """One movement-heavy serving run; returns its measurement dict.

    Two long requests on two instances, each repeatedly shipping prefix
    blocks to the other as its tail grows past the local quota — the
    Fig. 12 regime of sustained per-step movement traffic.
    """
    gc.collect()          # don't let the previous run's garbage bill us
    rng = np.random.default_rng(0)
    server = LLMServer(params, cfg, ServingConfig.smoke(
        n_instances=2, max_batch=2, max_local_len=max_local_len,
        pool_blocks=96, move_chunk_tokens=move_chunk, prefill_chunk=32,
        schedule_every=1000, async_movement=async_movement))
    handles = [server.submit(rng.integers(0, cfg.vocab_size, 40).tolist(),
                             SamplingParams(max_new_tokens=n_new))
               for _ in range(2)]
    cl = server.cluster
    t0 = time.perf_counter()
    server.drain(max_steps=600)
    cl.stager.commit()                    # drain before stopping the clock
    dt = time.perf_counter() - t0
    steps = sum(e.stats.decode_steps for e in cl.engines.values())
    copies = sum(e.stats.pool_copy_steps for e in cl.engines.values())
    return {
        "tps": sum(h.metrics["n_tokens"] for h in handles) / dt,
        "moved": cl.throughput_stats["kv_moved_bytes"],
        "gather_us": sum(e.stats.host_gather_s for e in cl.engines.values())
        / max(steps, 1) * 1e6,
        "steps": steps,
        "copies": copies,
        "sync_wait_ms": cl.stager.sync_wait_s * 1e3,
    }


def measured(csv=True):
    """Async-vs-serial movement A/B at several chunk sizes + a
    no-movement reference (quota big enough that nothing ships).

    Each timed config is sampled twice and the faster run is reported:
    single-shot CPU wall clocks here swing tens of percent with host
    scheduling, so best-of-2 keeps the gated on/off ratio about the
    serving code, not the machine. The donation counters (``steps`` /
    ``copies``) sum over EVERY run, sampled or not — one pool re-copy
    anywhere still fails ``decode_pool_zero_copy``.
    """
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    all_runs = []

    def sample(**kw):
        runs = [_run_cluster(params, cfg, **kw) for _ in range(2)]
        all_runs.extend(runs)
        return max(runs, key=lambda m: m["tps"])

    # Warm every jit signature (table buckets, rank counts) so the A/B
    # below times steady-state serving, not compilation.
    _run_cluster(params, cfg, move_chunk=16, async_movement=True,
                 max_local_len=96)
    for chunk in (8, 16, 32):
        _run_cluster(params, cfg, move_chunk=chunk, async_movement=True)
    # Reference: no movement ever triggers (quota covers prompt+decode).
    base = sample(move_chunk=16, async_movement=True, max_local_len=96)
    rows = []
    for chunk in (8, 16, 32):
        off = sample(move_chunk=chunk, async_movement=False)
        on = sample(move_chunk=chunk, async_movement=True)
        rows.append((chunk, on["tps"], off["tps"], on["moved"],
                     on["gather_us"]))
    steps = sum(m["steps"] for m in all_runs)
    copies = sum(m["copies"] for m in all_runs)
    if csv:
        print("fig12_measured_chunk,tps_overlap_on,tps_overlap_off,"
              "kv_moved_bytes,host_gather_us_per_step")
        for r in rows:
            print(f"{r[0]},{r[1]:.2f},{r[2]:.2f},{r[3]},{r[4]:.1f}")
        print(f"fig12_measured_no_move_tps,{base['tps']:.2f}")
    ratio = sum(r[1] for r in rows) / max(sum(r[2] for r in rows), 1e-9)
    be = max((r[0] for r in rows if r[1] >= base["tps"] * 0.9), default=0)
    if csv:
        # Informational (NOT in baselines.json): where overlap stops
        # hiding movement at CPU smoke scale, 0 (nothing hidden) to 32.
        print(f"fig12_overlap_breakeven_tokens_cpu,{be}")
    zero_copy = 1.0 - copies / max(steps, 1)
    return rows, {"tps_overlap_ratio_measured": ratio,
                  "overlap_breakeven_tokens_measured": be,
                  "overlap_breakeven_tokens_cpu": be,
                  "decode_pool_zero_copy": zero_copy}


def main():
    t0 = time.perf_counter()
    rows = modeled()
    mrows, mmetrics = measured()
    us = (time.perf_counter() - t0) * 1e6
    # break-even: largest m with overlapped == no-move throughput
    base = rows[0][3]
    be = max((r[0] for r in rows if r[3] >= base * 0.995), default=0)
    print(f"bench_kv_movement,{us:.1f},overlap_breakeven_tokens={be},"
          f"tps_overlap_ratio_measured="
          f"{mmetrics['tps_overlap_ratio_measured']:.3f},"
          f"decode_pool_zero_copy="
          f"{mmetrics['decode_pool_zero_copy']:.3f}")
    write_bench_json(
        "kv_movement",
        rows=[list(r) for r in rows] + [list(r) for r in mrows],
        config={"model_modeled": "mistral-nemo-12b", "chips": 8,
                "model_measured": "olmo-1b-smoke"},
        header=["tokens_per_step_or_chunk", "step_ms_or_tps_on",
                "move_ms_or_tps_off", "tps_overlap_or_moved_bytes",
                "tps_serial_or_gather_us"],
        metrics={"overlap_breakeven_tokens": be, **mmetrics})


if __name__ == "__main__":
    main()
