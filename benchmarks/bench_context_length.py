"""Paper Fig. 9: max supported context + throughput per policy.

For each model (7B/13B/70B-class: qwen3-0.6b stands in only for smoke;
here we use nemo-12B, starcoder2-15B, chameleon-34B) and each policy
(Infinite-LLM, vLLM-multi, vLLM-single), report (a) the longest context
servable with 32 chips and (b) decode throughput at a short (1k) and at
the max context — all from the calibrated perf/memory model.

Also reports PEAK ADMISSION KV-STAGING MEMORY: dense-cache admission
needs the whole [L, 1, T, K, hd] prompt KV resident (O(T)) before it can
scatter into blocks, while streaming paged prefill stages at most one
chunk's [L, C, K, hd] KV export (O(chunk)) — modeled per arch at each
policy's max context, and measured at smoke scale on the real engine via
``CommStats.admit_stage_bytes``. (Per-layer attention workspace is
common to both admission paths and excluded from both numbers.)
"""
from __future__ import annotations

import time

from repro.configs import get_config, get_smoke_config
from repro.serving.perfmodel import InstancePerfModel

try:
    from benchmarks.benchjson import write_bench_json
except ImportError:                      # run as a script from benchmarks/
    from benchjson import write_bench_json

TOTAL_CHIPS = 32
INST_CHIPS = 8
PREFILL_CHUNK = 512                 # production-scale streaming chunk


def _max_ctx_tokens(perf: InstancePerfModel) -> int:
    return perf.kv_tokens_capacity()


def run(csv=True):
    rows = []
    for arch in ("mistral-nemo-12b", "starcoder2-15b", "chameleon-34b"):
        cfg = get_config(arch)
        inst = InstancePerfModel(cfg, chips=INST_CHIPS)
        single = InstancePerfModel(cfg, chips=TOTAL_CHIPS)
        n_inst = TOTAL_CHIPS // INST_CHIPS

        # Max context: vllm-multi is capped by ONE instance's memory;
        # vllm-single by the whole cluster in one instance; infinite by
        # the cluster POOL (minus one instance's working set).
        cap_multi = _max_ctx_tokens(inst)
        cap_single = _max_ctx_tokens(single)
        cap_inf = _max_ctx_tokens(inst) * n_inst

        # Short-context throughput (1k ctx, saturating batch):
        def short_tps(perf, n_copies):
            beta = 256
            return n_copies * perf.tps(beta, [1024] * beta)

        tp_multi = short_tps(inst, n_inst)
        tp_single = short_tps(single, 1)
        tp_inf = short_tps(inst, n_inst)          # same parallelism!

        # Long-context throughput at each policy's own max length:
        def long_tps(perf, ctx, n_copies=1, offload=0):
            return n_copies * perf.tps(1, [ctx], offloaded_tokens=offload)

        tl_multi = long_tps(inst, cap_multi)
        tl_single = long_tps(single, cap_single)
        tl_inf = long_tps(inst, cap_inf, offload=cap_inf - cap_multi)

        # Peak admission KV staging at the infinite policy's max
        # context: dense-cache admission stages O(T); streaming O(chunk).
        per_tok = cfg.kv_bytes_per_token()
        admit_dense_gb = cap_inf * per_tok / 2**30
        admit_chunk_gb = PREFILL_CHUNK * per_tok / 2**30

        rows.append((arch, cap_multi, cap_single, cap_inf,
                     tp_multi, tp_single, tp_inf,
                     tl_multi, tl_single, tl_inf,
                     admit_dense_gb, admit_chunk_gb))
    if csv:
        print("fig9_arch,maxctx_vllm_multi,maxctx_vllm_single,"
              "maxctx_infinite,short_tps_multi,short_tps_single,"
              "short_tps_infinite,long_tps_multi,long_tps_single,"
              "long_tps_infinite,admit_stage_dense_gb,admit_stage_chunk_gb")
        for r in rows:
            print(",".join(str(x) if isinstance(x, (int, str))
                           else f"{x:.3f}" for x in r))
    return rows


def measured_admission(csv=True):
    """Real-engine measurement at smoke scale: peak prompt-KV bytes the
    streaming admission staged (``CommStats.admit_stage_bytes``) vs the
    dense [L, 1, T, K, hd] cache the old path materialized."""
    import jax
    import numpy as np

    from repro.models.model import init_params
    from repro.serving import LLMServer, SamplingParams, ServingConfig

    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    T, chunk = 96, 16
    server = LLMServer(params, cfg, ServingConfig.smoke(
        n_instances=1, max_batch=1, max_local_len=128, pool_blocks=32,
        prefill_chunk=chunk))
    h = server.submit(rng.integers(0, cfg.vocab_size, T).tolist(),
                      SamplingParams(max_new_tokens=1))
    h.result()
    peak = server.cluster.engines[0].stats.admit_stage_bytes
    dense = T * cfg.kv_bytes_per_token()
    if csv:
        print("admit_measured_T,chunk,admit_stage_bytes_chunked,"
              "admit_stage_bytes_dense,reduction")
        print(f"{T},{chunk},{peak},{dense},{dense / max(peak, 1):.1f}x")
    return peak, dense


def main():
    t0 = time.perf_counter()
    rows = run()
    peak, dense = measured_admission()
    us = (time.perf_counter() - t0) * 1e6
    r = rows[0]
    print(f"bench_context_length,{us:.1f},"
          f"ctx_gain_vs_multi={r[3] / r[1]:.1f}x,"
          f"short_tps_gain_vs_single={r[6] / r[5]:.2f}x,"
          f"admit_mem_reduction={r[10] / r[11]:.0f}x")
    write_bench_json(
        "context_length", rows=rows,
        config={"total_chips": TOTAL_CHIPS, "inst_chips": INST_CHIPS,
                "prefill_chunk": PREFILL_CHUNK},
        header=["arch", "maxctx_vllm_multi", "maxctx_vllm_single",
                "maxctx_infinite", "short_tps_multi", "short_tps_single",
                "short_tps_infinite", "long_tps_multi",
                "long_tps_single", "long_tps_infinite",
                "admit_stage_dense_gb", "admit_stage_chunk_gb"],
        metrics={"ctx_gain_vs_multi": r[3] / r[1],
                 "short_tps_gain_vs_single": r[6] / r[5],
                 "admit_mem_reduction": r[10] / r[11],
                 "admit_measured_reduction": dense / max(peak, 1)})


if __name__ == "__main__":
    main()
