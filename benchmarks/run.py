"""Benchmark harness: one module per paper table/figure.

Each prints CSV rows followed by a ``name,us_per_call,derived`` summary
AND writes a machine-readable ``BENCH_<name>.json`` at the repo root
(rows + config + git sha + key metrics). This harness aggregates the
per-bench JSONs into ``BENCH_summary.json``; CI uploads everything as
artifacts and gates the metrics with ``benchmarks/compare.py``.
Run: PYTHONPATH=src python -m benchmarks.run [filter]
"""
from __future__ import annotations

import json
import sys
import time
import traceback

from benchmarks import (bench_chaos, bench_context_length,
                        bench_debtor_creditor, bench_distattn_methods,
                        bench_e2e_traces, bench_kv_movement,
                        bench_overload, bench_prefix_cache,
                        bench_sharded_pool, bench_ship_query_vs_kv)
from benchmarks.benchjson import REPO_ROOT, collect_bench_jsons, git_sha

BENCHES = [
    ("fig4c_ship_query_vs_kv", bench_ship_query_vs_kv.main),
    ("fig7_debtor_creditor", bench_debtor_creditor.main),
    ("fig9_context_length", bench_context_length.main),
    ("fig10_table1_e2e_traces", bench_e2e_traces.main),
    ("fig11_distattn_methods", bench_distattn_methods.main),
    ("fig12_kv_movement", bench_kv_movement.main),
    ("issue6_prefix_cache", bench_prefix_cache.main),
    ("issue7_sharded_pool", bench_sharded_pool.main),
    ("issue8_overload", bench_overload.main),
    ("issue9_chaos", bench_chaos.main),
]


def aggregate() -> dict:
    """Merge every BENCH_<name>.json into BENCH_summary.json."""
    docs = collect_bench_jsons()
    summary = {
        "git_sha": git_sha(),
        "benches": sorted(docs),
        "metrics": {name: doc.get("metrics", {})
                    for name, doc in docs.items()},
    }
    out = REPO_ROOT / "BENCH_summary.json"
    out.write_text(json.dumps(summary, indent=2) + "\n")
    print(f"# wrote {out} ({len(docs)} bench files aggregated)")
    return summary


def main() -> None:
    pat = sys.argv[1] if len(sys.argv) > 1 else ""
    failures = 0
    for name, fn in BENCHES:
        if pat and pat not in name:
            continue
        print(f"\n=== {name} ===")
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},FAILED,")
        print(f"# {name} total {(time.perf_counter() - t0):.1f}s")
    aggregate()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
