"""Paper Fig. 10 + Table 1: end-to-end serving on the 9 generated traces,
plus the MEASURED open-loop serving frontend.

Two sections:

  * Fig. 10 protocol — event-driven simulation (perf-model-timed, v5e
    constants): Infinite-LLM vs vLLM-multi on short traces 0-2
    (Fig. 10a) and vs vLLM-single on long traces 3-8 (Fig. 10b), plus
    the Table-1 stats of the generated traces.
  * Frontend — a REAL smoke-scale ``LLMServer`` serving a compressed
    trace through the open-loop ``server.run()`` event pump (Poisson
    arrivals, admission backpressure, per-request timestamps), emitting
    the per-request latency percentiles the serving frontend is judged
    by: ``ttft_p50/p99`` and ``tbt_p99``. Their inverses are the
    CI-gated metrics (the gate convention is higher-is-better).
"""
from __future__ import annotations

import time

import jax

from repro.configs import get_config, get_smoke_config
from repro.models.model import init_params
from repro.serving import LLMServer, ServingConfig
from repro.serving.simulator import SimRequest, make_policy_cluster

try:
    from benchmarks.benchjson import write_bench_json
    from benchmarks.traces import (TRACE_SPECS, gen_multitenant_trace,
                                   gen_trace, multitenant_arrivals,
                                   to_arrivals, trace_stats)
except ImportError:                      # run as a script from benchmarks/
    from benchjson import write_bench_json
    from traces import (TRACE_SPECS, gen_multitenant_trace, gen_trace,
                        multitenant_arrivals, to_arrivals, trace_stats)

TOTAL_CHIPS = 32
# Instance sizes chosen to match the paper's memory-pressure regime
# (per-instance KV capacity ~50-100x the trace's average length): small
# TP for short traces (the paper's DP8xTP1-like rows), one-node TP for
# long traces.
INST_CHIPS_SHORT = 4
INST_CHIPS_LONG = 8
N_REQ = {0: 300, 1: 300, 2: 300, 3: 32, 4: 32, 5: 20, 6: 20, 7: 10, 8: 8}
RATE = {0: 24.0, 1: 24.0, 2: 24.0, 3: 0.8, 4: 0.5, 5: 0.3, 6: 0.4,
        7: 0.15, 8: 0.1}


def _to_sim(reqs):
    return [SimRequest(req_id=i, arrival=r.arrival,
                       prompt_len=r.prompt_len, output_len=r.output_len)
            for i, r in enumerate(reqs)]


def run(csv=True, horizon=2000.0):
    """Paper protocol: sweep request rates per policy, report the MAX
    achieved throughput (Fig. 10 compares maximum achieved tput)."""
    cfg = get_config("mistral-nemo-12b")
    rows = []
    for tid in sorted(TRACE_SPECS):
        base_policy = "vllm-multi" if tid <= 2 else "vllm-single"
        inst_chips = INST_CHIPS_SHORT if tid <= 2 else INST_CHIPS_LONG
        res = {}
        for policy in ("infinite", base_policy):
            best = None
            for mult in (0.5, 1.0, 2.0):
                reqs = gen_trace(tid, N_REQ[tid], RATE[tid] * mult)
                sim = make_policy_cluster(cfg, policy, TOTAL_CHIPS,
                                          inst_chips)
                r = sim.run(_to_sim(reqs), horizon=horizon)
                if best is None or r["throughput_tok_s"] > \
                        best["throughput_tok_s"]:
                    best = r
            res[policy] = best
        inf, base = res["infinite"], res[base_policy]
        gain = inf["throughput_tok_s"] / max(base["throughput_tok_s"],
                                             1e-9)
        rows.append((tid, base_policy, inf["throughput_tok_s"],
                     base["throughput_tok_s"], gain, inf["finished"],
                     base["finished"], inf["failed"], base["failed"]))
    if csv:
        print("fig10_trace,baseline,inf_tps,base_tps,gain,"
              "inf_done,base_done,inf_fail,base_fail")
        for r in rows:
            print(f"{r[0]},{r[1]},{r[2]:.0f},{r[3]:.0f},{r[4]:.2f},"
                  f"{r[5]},{r[6]},{r[7]},{r[8]}")
    return rows


def print_table1(csv=True):
    if csv:
        print("table1_trace,target_range,target_avg,target_sd,"
              "gen_avg,gen_sd,gen_min,gen_max")
        for tid, (rmax, avg, sd) in sorted(TRACE_SPECS.items()):
            ga, gs, gmin, gmax = trace_stats(tid)
            print(f"{tid},1-{rmax},{avg},{sd:.0f},{ga:.0f},{gs:.0f},"
                  f"{gmin},{gmax}")


def run_frontend(csv=True, n_req=10):
    """Measured open-loop serving: a smoke LLMServer pumps a compressed
    trace-0 workload through ``server.run()`` and reports the
    per-request TTFT/TBT percentiles (wall-clock, CPU smoke scale)."""
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)

    def one_run():
        server = LLMServer(params, cfg,
                           ServingConfig.smoke(n_instances=2, max_batch=4,
                                               pool_blocks=64))
        arrivals = to_arrivals(gen_trace(0, n_req, rate=24.0, seed=1),
                               cfg.vocab_size, seed=1,
                               max_prompt=40, max_output=8,
                               time_scale=0.5)
        return server.run(arrivals)

    one_run()                            # warm every jit signature
    stats = one_run()                    # measured, steady state
    assert stats["finished"] == n_req, \
        f"frontend dropped requests: {stats}"
    if csv:
        print("frontend_metric,value")
        for k in ("throughput_tok_s", "ttft_p50", "ttft_p99",
                  "tbt_p50", "tbt_p99", "finished", "wall_s"):
            print(f"{k},{stats[k]:.4f}")
    return stats


def run_frontend_multitenant(csv=True, n_req=16):
    """Measured open-loop multi-tenant serving WITH the prefix cache: the
    same frontend pump fed a shared-system-prompt workload, reporting
    the achieved hit-rate beside the latency percentiles (the cache's
    effect under dynamic traffic, not just the isolated A/B that
    ``bench_prefix_cache`` runs)."""
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = LLMServer(params, cfg,
                       ServingConfig.smoke(n_instances=2, max_batch=4,
                                           max_local_len=64,
                                           pool_blocks=64,
                                           prefix_cache=True,
                                           host_tier_blocks=128))
    reqs = gen_multitenant_trace(n_req, rate=24.0, n_tenants=2,
                                 reuse_p=0.75, body_avg=8,
                                 output_len=6, seed=4)
    arrivals, reused = multitenant_arrivals(
        reqs, cfg.vocab_size, n_tenants=2, prefix_len=24, seed=4,
        time_scale=0.5, max_body=16)
    stats = server.run(arrivals)
    cs = server.cluster.prefix_cache.stats
    stats["hit_rate"] = cs.hits / max(1, cs.lookups)
    stats["reuse_ceiling"] = sum(reused) / max(1, len(reused))
    stats["cache_hit_tokens"] = server.metrics["cache_hit_tokens"]
    if csv:
        print("multitenant_metric,value")
        for k in ("finished", "hit_rate", "reuse_ceiling",
                  "cache_hit_tokens", "throughput_tok_s", "ttft_p50"):
            print(f"{k},{stats[k]:.4f}")
    return stats


def main():
    t0 = time.perf_counter()
    print_table1()
    rows = run()
    fe = run_frontend()
    mt = run_frontend_multitenant()
    us = (time.perf_counter() - t0) * 1e6
    short_g = [r[4] for r in rows if r[0] <= 2]
    long_g = [r[4] for r in rows if r[0] >= 3]
    print(f"bench_e2e_traces,{us:.1f},"
          f"gain_short={min(short_g):.2f}-{max(short_g):.2f}x,"
          f"gain_long={min(long_g):.2f}-{max(long_g):.2f}x,"
          f"ttft_p50={fe['ttft_p50'] * 1e3:.1f}ms,"
          f"tbt_p99={fe['tbt_p99'] * 1e3:.1f}ms")
    write_bench_json(
        "e2e_traces", rows=rows,
        config={"model": "mistral-nemo-12b", "total_chips": TOTAL_CHIPS,
                "inst_chips_short": INST_CHIPS_SHORT,
                "inst_chips_long": INST_CHIPS_LONG, "n_req": N_REQ,
                "rate": RATE, "frontend_model": "olmo-1b-smoke"},
        header=["trace", "baseline", "inf_tps", "base_tps", "gain",
                "inf_done", "base_done", "inf_fail", "base_fail"],
        metrics={"gain_short_min": min(short_g),
                 "gain_long_min": min(long_g),
                 # Raw percentiles (informational) + gated inverses —
                 # the CI gate convention is higher-is-better, so
                 # lower-is-better latencies are gated via 1/x.
                 "ttft_p50": fe["ttft_p50"],
                 "ttft_p99": fe["ttft_p99"],
                 "tbt_p99": fe["tbt_p99"],
                 "ttft_p50_inv": 1.0 / max(fe["ttft_p50"], 1e-9),
                 "ttft_p99_inv": 1.0 / max(fe["ttft_p99"], 1e-9),
                 "tbt_p99_inv": 1.0 / max(fe["tbt_p99"], 1e-9),
                 # Multi-tenant prefix-cache frontend (informational
                 # here; the hard gates live in bench_prefix_cache).
                 "mt_hit_rate": mt["hit_rate"],
                 "mt_finished": mt["finished"]})


if __name__ == "__main__":
    main()
