"""Mesh-sharded global KV pool: decode-throughput scaling over ranks.

The tentpole claim of the global pool is that adding ranks adds
serving capacity WITHOUT moving KV: each rank's shard computes its
paged MicroAttention partial in place and only the per-token LSE-merge
scalars (o, m, l) cross the mesh. This bench measures wall-clock decode
tokens/s of the in-process cluster running over ONE mesh-sharded
[R, L, NB, bs, K, hd] tensor at R = 1, 2, 4 ranks, with the offered
load scaled with R (every rank serves a full decode batch), and reports
the analytic per-step collective bytes of the merge alongside.

Gated metric: ``tps_ratio_4_over_1`` — aggregate throughput at 4 ranks
over 1 rank. On CPU the "mesh" is fake host devices sharing the same
cores, so the ratio is far below 4x; the gate only catches the pooled
step's cross-rank plumbing getting slower (e.g. a merge that starts
shipping KV instead of scalars). ``tps_r*`` rows are informational.

Mesh-rank scaling needs ``--xla_force_host_platform_device_count`` set
BEFORE jax imports, so main() re-execs this file as a subprocess worker
with the flag in its environment (same pattern as the sharded tests).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

RANKS = (1, 2, 4)
N_NEW = 24
PER_RANK_REQS = 2


def worker():
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving import (Cluster, Request, SamplingParams,
                               ServingConfig)
    from repro.serving.sharded_step import ServeLayout

    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    L, H, hd = cfg.num_layers, cfg.num_heads, cfg.head_dim
    rng = np.random.default_rng(0)
    out = []
    for R in RANKS:
        mesh = jax.make_mesh((R, 1), ("data", "model"))
        layout = ServeLayout(batch_axes=("data",), pool_axes=("data",))
        prompts = [list(rng.integers(0, cfg.vocab_size, size=12))
                   for _ in range(PER_RANK_REQS * R)]

        def run():
            cl = Cluster(params, cfg, ServingConfig.smoke(
                n_instances=R, max_batch=PER_RANK_REQS, pool_blocks=48,
                global_pool=True, schedule_every=1000),
                mesh=mesh, layout=layout)
            reqs = [Request(prompt=p,
                            sampling=SamplingParams(max_new_tokens=N_NEW))
                    for p in prompts]
            for r in reqs:
                cl.submit(r)
            t0 = time.perf_counter()
            cl.run_until_done(max_steps=600)
            dt = time.perf_counter() - t0
            assert all(r.done for r in reqs)
            return sum(len(r.output) for r in reqs) / dt

        run()                            # warm the jit signatures
        tps = run()
        # Per decode step each of R shards contributes its (o, m, l)
        # partial to the collective merge for every slot on every layer:
        # o = H*hd floats, m + l = 2*H floats, f32 scalars on the wire.
        batch = PER_RANK_REQS * R
        coll_bytes = (R - 1) * L * batch * (H * hd + 2 * H) * 4
        out.append({"ranks": R, "tps": tps,
                    "collective_bytes_per_step": coll_bytes})
    print("WORKER_RESULT " + json.dumps(out))


def main():
    try:
        from benchmarks.benchjson import write_bench_json
    except ImportError:
        from benchjson import write_bench_json

    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4")
    env.setdefault("PYTHONPATH", os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "src"))
    t0 = time.perf_counter()
    r = subprocess.run([sys.executable, os.path.abspath(__file__),
                        "--worker"], env=env, capture_output=True,
                       text=True, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"sharded-pool worker failed:\n{r.stdout}\n"
                           f"{r.stderr}")
    line = next(l for l in r.stdout.splitlines()
                if l.startswith("WORKER_RESULT "))
    rows = json.loads(line[len("WORKER_RESULT "):])
    us = (time.perf_counter() - t0) * 1e6
    by_rank = {row["ranks"]: row for row in rows}
    ratio = by_rank[4]["tps"] / by_rank[1]["tps"]
    print("sharded_pool_ranks,tokens_per_s,collective_bytes_per_step")
    for row in rows:
        print(f"{row['ranks']},{row['tps']:.2f},"
              f"{row['collective_bytes_per_step']}")
    print(f"bench_sharded_pool,{us:.1f},tps_ratio_4_over_1={ratio:.3f}")
    write_bench_json(
        "sharded_pool",
        rows=[[row["ranks"], row["tps"],
               row["collective_bytes_per_step"]] for row in rows],
        config={"model": "olmo-1b-smoke", "ranks": list(RANKS),
                "per_rank_reqs": PER_RANK_REQS, "n_new": N_NEW,
                "pool_axes": ["data"], "backend": "cpu-fake-devices"},
        header=["ranks", "tokens_per_s", "collective_bytes_per_step"],
        metrics={"tps_ratio_4_over_1": ratio,
                 "tps_r1": by_rank[1]["tps"],
                 "tps_r4": by_rank[4]["tps"]})


if __name__ == "__main__":
    if "--worker" in sys.argv:
        worker()
    else:
        main()
