"""Paper Fig. 4(c): ship-the-query vs ship-the-KVCache, per context length.

Bytes are exact (model dims); times are modeled on the v5e interconnect
(ICI intra-pod, DCN cross-pod) — the paper's A100 numbers used NVLink.
Also measures the REAL per-step merge traffic of the in-process cluster
engine for a small config, confirming the query-side bytes.
"""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.distributed.hardware import V5E

try:
    from benchmarks.benchjson import write_bench_json
except ImportError:                      # run as a script from benchmarks/
    from benchjson import write_bench_json


def run(csv=True):
    cfg = get_config("mistral-nemo-12b")     # LLaMA2-13B-class dims
    rows = []
    for ctx in (8192, 16384, 32768, 65536, 131072):
        # Query round trip per layer: q + (o, m, l) partial (paper: "query
        # vector along with only two float values").
        q_bytes = cfg.num_heads * cfg.head_dim * 2
        merge_bytes = cfg.num_heads * cfg.head_dim * 4 + 2 * cfg.num_heads \
            * 4
        ship_query = (q_bytes + merge_bytes) * cfg.num_layers
        ship_kv = ctx * cfg.kv_bytes_per_token()
        t_query_ici = ship_query / V5E.ici_link_bw
        t_kv_ici = ship_kv / V5E.ici_link_bw
        t_query_dcn = ship_query / V5E.dcn_bw
        t_kv_dcn = ship_kv / V5E.dcn_bw
        rows.append((ctx, ship_query, ship_kv, t_query_ici * 1e3,
                     t_kv_ici * 1e3, t_query_dcn * 1e3, t_kv_dcn * 1e3))
    if csv:
        print("fig4c_ctx,ship_query_bytes,ship_kv_bytes,"
              "t_query_ici_ms,t_kv_ici_ms,t_query_dcn_ms,t_kv_dcn_ms")
        for r in rows:
            print(",".join(f"{v:.4g}" for v in r))
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6
    ratio = rows[-1][2] / rows[-1][1]
    print(f"bench_ship_query_vs_kv,{us:.1f},kv_over_query_bytes_131k="
          f"{ratio:.0f}x")
    write_bench_json(
        "ship_query_vs_kv", rows=rows,
        config={"model": "mistral-nemo-12b"},
        header=["ctx", "ship_query_bytes", "ship_kv_bytes",
                "t_query_ici_ms", "t_kv_ici_ms", "t_query_dcn_ms",
                "t_kv_dcn_ms"],
        metrics={"kv_over_query_bytes_131k": ratio})


if __name__ == "__main__":
    main()
