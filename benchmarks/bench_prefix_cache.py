"""Prefix-cache + host-DRAM tier benchmark (ISSUE 6 acceptance gates).

Three measured sections on a real smoke-scale ``LLMServer``:

  * warm-vs-cold TTFT — one tenant's long system prompt served cold,
    then repeatedly warm: the radix cache pins the shared blocks and
    admission streams only the tail, so warm TTFT must be >= 2x better
    (gated as ``ttft_warm_cold_ratio``).
  * token identity — every warm output is compared token-for-token
    against a cache-disabled server on the same prompts; the cache may
    never change what the model says (gated as ``token_identity``).
  * host-tier overlap — a pool too small for the tenant working set
    forces cache replicas to spill to host DRAM and prefetch back on
    re-use; D2H/H2D is dispatched async behind decode, so the fraction
    of prefetches that actually stall must stay <= 0.1 (gated as its
    complement ``prefetch_overlap``).

Plus a multi-tenant trace (``benchmarks.traces.gen_multitenant_trace``)
through the open-loop pump, reporting the achieved hit-rate against the
trace's reuse ceiling.
"""
from __future__ import annotations

import time

import jax

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serving import LLMServer, ServingConfig
from repro.serving.request import SamplingParams

try:
    from benchmarks.benchjson import write_bench_json
    from benchmarks.traces import gen_multitenant_trace, multitenant_arrivals
except ImportError:                      # run as a script from benchmarks/
    from benchjson import write_bench_json
    from traces import gen_multitenant_trace, multitenant_arrivals

PREFIX_LEN = 88          # 11 blocks of 8: the shared system prompt
N_WARM = 4
N_TENANTS = 3
REUSE_P = 0.75


def _server(params, cfg, **over):
    base = dict(n_instances=1, max_batch=2, max_local_len=128,
                pool_blocks=64, prefill_chunk=8,
                prefix_cache=True, host_tier_blocks=128)
    base.update(over)
    return LLMServer(params, cfg, ServingConfig.smoke(**base))


def run_warm_cold(params, cfg, csv=True):
    """Cold prefill vs cached-prefix admission TTFT on one tenant."""
    import numpy as np
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, size=PREFIX_LEN).tolist()

    def serve(server, tail_seed):
        t_rng = np.random.default_rng(tail_seed)
        prompt = prefix + t_rng.integers(0, cfg.vocab_size,
                                         size=4).tolist()
        h = server.submit(prompt, SamplingParams(max_new_tokens=6))
        out = h.result()
        return h.metrics["ttft"], prompt, out

    warm_srv = _server(params, cfg)
    serve(warm_srv, 999)                         # jit warm-up
    cold_srv = _server(params, cfg)              # fresh cache: cold
    ttft_cold, _, _ = serve(cold_srv, 0)
    ttfts, outs, prompts = [], [], []
    for i in range(N_WARM):                      # cold_srv now has the
        t, p, o = serve(cold_srv, i)             # prefix cached: warm
        ttfts.append(t)
        prompts.append(p)
        outs.append(o)
    ttft_warm = sum(ttfts) / len(ttfts)
    ratio = ttft_cold / max(ttft_warm, 1e-9)
    # Token identity: the same prompts on a cache-disabled server.
    ref_srv = _server(params, cfg, prefix_cache=False, host_tier_blocks=0)
    identical = all(
        ref_srv.submit(p, SamplingParams(max_new_tokens=6)).result() == o
        for p, o in zip(prompts, outs))
    hit_toks = cold_srv.metrics["cache_hit_tokens"]
    if csv:
        print("warmcold_metric,value")
        print(f"ttft_cold_ms,{ttft_cold * 1e3:.2f}")
        print(f"ttft_warm_ms,{ttft_warm * 1e3:.2f}")
        print(f"ttft_warm_cold_ratio,{ratio:.2f}")
        print(f"cache_hit_tokens,{hit_toks:.0f}")
        print(f"token_identity,{float(identical):.0f}")
    return dict(ttft_cold=ttft_cold, ttft_warm=ttft_warm, ratio=ratio,
                token_identity=float(identical), hit_tokens=hit_toks)


def run_host_overlap(params, cfg, csv=True):
    """Spill the tenant working set to host DRAM, prefetch it back, and
    measure how often a prefetch actually blocked decode."""
    import numpy as np
    srv = _server(params, cfg, pool_blocks=18, max_batch=1,
                  host_tier_blocks=256)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=40).tolist()
               for _ in range(4)]
    outs = []
    for p in prompts:                            # cold: fills + spills
        outs.append(srv.submit(p, SamplingParams(max_new_tokens=4))
                    .result())
    warm_ok = True
    for p, o in zip(prompts, outs):              # warm: prefetch chains
        warm_ok &= srv.submit(p, SamplingParams(max_new_tokens=4)) \
            .result() == o
    ts = srv.cluster.host_tier.stats
    stg = srv.cluster.stager
    prefetch_ops = ts.fetches + stg.stalls.get("prefetch", 0)
    stalls = ts.fetch_stalls + stg.stalls.get("prefetch", 0)
    stall_ratio = stalls / max(1, ts.fetches)
    m = srv.metrics
    if csv:
        print("hosttier_metric,value")
        print(f"spill_bytes,{m['host_spill_bytes']:.0f}")
        print(f"prefetch_bytes,{m['host_prefetch_bytes']:.0f}")
        print(f"fetches,{ts.fetches}")
        print(f"fetch_stalls,{stalls}")
        print(f"prefetch_stall_ratio,{stall_ratio:.3f}")
        print(f"warm_identical,{float(warm_ok):.0f}")
    assert m["host_spill_bytes"] > 0, "pool never spilled to host tier"
    assert m["host_prefetch_bytes"] > 0, "warm run never prefetched"
    return dict(spill_bytes=m["host_spill_bytes"],
                prefetch_bytes=m["host_prefetch_bytes"],
                stall_ratio=stall_ratio, warm_ok=float(warm_ok),
                prefetch_ops=prefetch_ops)


def run_multitenant(params, cfg, csv=True, n_req=24):
    """Open-loop multi-tenant trace: achieved hit-rate vs reuse ceiling."""
    srv = _server(params, cfg, max_batch=3, pool_blocks=96)
    reqs = gen_multitenant_trace(n_req, rate=30.0, n_tenants=N_TENANTS,
                                 reuse_p=REUSE_P, body_avg=8,
                                 output_len=4, seed=2)
    arrivals, reused = multitenant_arrivals(
        reqs, cfg.vocab_size, n_tenants=N_TENANTS, prefix_len=24,
        seed=2, time_scale=0.25, max_body=16)
    stats = srv.run(arrivals)
    cs = srv.cluster.prefix_cache.stats
    hit_rate = cs.hits / max(1, cs.lookups)
    reuse_ceiling = sum(reused) / max(1, len(reused))
    m = srv.metrics
    if csv:
        print("multitenant_metric,value")
        print(f"n_requests,{stats['n_requests']:.0f}")
        print(f"finished,{stats['finished']:.0f}")
        print(f"lookups,{cs.lookups}")
        print(f"hits,{cs.hits}")
        print(f"hit_rate,{hit_rate:.3f}")
        print(f"reuse_ceiling,{reuse_ceiling:.3f}")
        print(f"cache_hit_tokens,{m['cache_hit_tokens']:.0f}")
        print(f"throughput_tok_s,{stats['throughput_tok_s']:.1f}")
    return dict(hit_rate=hit_rate, reuse_ceiling=reuse_ceiling,
                finished=stats["finished"], n=stats["n_requests"],
                hit_tokens=m["cache_hit_tokens"],
                tput=stats["throughput_tok_s"])


def main():
    t0 = time.perf_counter()
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    wc = run_warm_cold(params, cfg)
    ho = run_host_overlap(params, cfg)
    mt = run_multitenant(params, cfg)
    us = (time.perf_counter() - t0) * 1e6
    print(f"bench_prefix_cache,{us:.1f},"
          f"warm_cold={wc['ratio']:.2f}x,"
          f"stall_ratio={ho['stall_ratio']:.3f},"
          f"hit_rate={mt['hit_rate']:.2f}")
    write_bench_json(
        "prefix_cache",
        rows=[["warm_cold", wc["ttft_cold"], wc["ttft_warm"],
               wc["ratio"], wc["hit_tokens"]],
              ["host_overlap", ho["spill_bytes"], ho["prefetch_bytes"],
               ho["stall_ratio"], ho["warm_ok"]],
              ["multitenant", mt["n"], mt["finished"], mt["hit_rate"],
               mt["hit_tokens"]]],
        config={"model": "olmo-1b-smoke", "prefix_len": PREFIX_LEN,
                "n_warm": N_WARM, "n_tenants": N_TENANTS,
                "reuse_p": REUSE_P},
        header=["section", "a", "b", "c", "d"],
        metrics={
            # All gated metrics are higher-is-better.
            "ttft_warm_cold_ratio": wc["ratio"],
            "token_identity": wc["token_identity"] * ho["warm_ok"],
            "prefetch_overlap": 1.0 - ho["stall_ratio"],
            # Hard gate on the <= 0.1 stall-ratio acceptance bound.
            "prefetch_overlap_ok": float(ho["stall_ratio"] <= 0.1),
            "hit_rate": mt["hit_rate"],
        })


if __name__ == "__main__":
    main()
