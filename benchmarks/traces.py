"""Table 1 trace generator: 9 traces matching the paper's ranges/avg/SD.

Traces 0-2 ("S"): ShareGPT4-like short conversations (log-normal body,
range 1-60k, decreasing SD). Traces 3-8 ("L"): long-context mixes with
the paper's ranges and means. Lengths are drawn from a two-component
mix (bulk log-normal + long tail) and clipped to the range; output
lengths are a fraction of the context.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

# (range_max, target_avg, target_sd)  — paper Table 1
TRACE_SPECS = {
    0: (60_000, 1_233, 7_785.68),
    1: (60_000, 712, 5_531.40),
    2: (60_000, 469, 3_506.36),
    3: (200_000, 56_362, 28_787.78),
    4: (280_000, 75_650, 39_479.42),
    5: (600_000, 160_239, 87_906.67),
    6: (480_000, 128_804, 70_647.93),
    7: (1_200_000, 293_945, 172_169.14),
    8: (2_000_000, 498_609, 261_817.24),
}


@dataclass
class TraceRequest:
    arrival: float
    prompt_len: int
    output_len: int


def gen_lengths(trace_id: int, n: int, seed: int = 0) -> np.ndarray:
    rmax, avg, sd = TRACE_SPECS[trace_id]
    rng = np.random.default_rng(seed * 100 + trace_id)
    if trace_id <= 2:
        # Table 1's short traces have sd >> avg with a hard range cap —
        # i.e. a near-two-point law: a low lognormal bulk (typical chats)
        # plus a rare near-rmax tail. Solve the tail fraction f and bulk
        # mean b analytically from the first two target moments (tail ~
        # U[0.8 rmax, rmax]: mean 0.9 rmax, E[t^2] ~ 0.8133 rmax^2).
        f = (sd ** 2 + avg ** 2) / (0.8133 * rmax ** 2)
        b = max((avg - f * 0.9 * rmax) / (1.0 - f), 16.0)
        sigma = 1.0
        mu = np.log(b) - sigma ** 2 / 2.0
        bulk = rng.lognormal(mu, sigma, size=n)
        tail_mask = rng.random(n) < f
        tail = rng.uniform(0.8 * rmax, rmax, size=n)
        lens = np.where(tail_mask, tail, bulk)
    else:
        # Long traces: normal around avg with the table SD.
        lens = rng.normal(avg, sd, size=n)
    return np.clip(lens, 1, rmax).astype(np.int64)


def gen_trace(trace_id: int, n: int, rate: float, seed: int = 0,
              output_frac: float = 0.1, max_output: int = 2048
              ) -> List[TraceRequest]:
    """Poisson arrivals at ``rate`` req/s with Table-1 length marginals."""
    rng = np.random.default_rng(seed * 7919 + trace_id)
    lens = gen_lengths(trace_id, n, seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    t = np.cumsum(gaps)
    out = np.minimum(np.maximum((lens * output_frac).astype(np.int64), 8),
                     max_output)
    return [TraceRequest(float(t[i]), int(lens[i] - out[i]) or 1,
                         int(out[i])) for i in range(n)]


def trace_stats(trace_id: int, n: int = 5000, seed: int = 0
                ) -> Tuple[float, float, int, int]:
    lens = gen_lengths(trace_id, n, seed)
    return float(lens.mean()), float(lens.std()), int(lens.min()), \
        int(lens.max())


# --- multi-tenant traces (prefix-cache workloads) ----------------------- #
@dataclass
class TenantRequest:
    """One multi-tenant trace event: ``tenant`` selects which shared
    system prompt the request reuses (-1 = a fresh, uncachable prompt)."""
    arrival: float
    tenant: int
    body_len: int
    output_len: int


def gen_multitenant_trace(n: int, rate: float, *, n_tenants: int = 4,
                          reuse_p: float = 0.8, body_avg: int = 24,
                          output_len: int = 8, seed: int = 0
                          ) -> List[TenantRequest]:
    """Multi-tenant request stream for prefix-cache evaluation.

    Each of ``n_tenants`` tenants owns one fixed system prompt; every
    request reuses its tenant's prompt with probability ``reuse_p``
    (otherwise it is a one-off fresh prompt, tenant -1). Arrivals are
    Poisson at ``rate`` req/s; per-request bodies are geometric around
    ``body_avg`` so tail lengths vary. The knobs sweep the cache regime:
    ``n_tenants`` sets working-set size vs device/host capacity,
    ``reuse_p`` the achievable hit-rate ceiling."""
    rng = np.random.default_rng(seed * 7919 + 13)
    gaps = rng.exponential(1.0 / rate, size=n)
    t = np.cumsum(gaps)
    tenants = np.where(rng.random(n) < reuse_p,
                       rng.integers(0, n_tenants, size=n), -1)
    bodies = np.maximum(1, rng.geometric(1.0 / body_avg, size=n))
    return [TenantRequest(float(t[i]), int(tenants[i]), int(bodies[i]),
                          output_len) for i in range(n)]


def tenant_prompts(n_tenants: int, prefix_len: int, vocab_size: int,
                   seed: int = 0) -> List[List[int]]:
    """The per-tenant shared system prompts (deterministic in seed)."""
    rng = np.random.default_rng(seed * 104729 + 7)
    return [rng.integers(0, vocab_size, size=prefix_len).tolist()
            for _ in range(n_tenants)]


def multitenant_arrivals(reqs: List[TenantRequest], vocab_size: int, *,
                         n_tenants: int = 4, prefix_len: int = 64,
                         seed: int = 0, time_scale: float = 1.0,
                         max_body: int = 10 ** 9):
    """Materialize a multi-tenant trace as ``serving.Arrival``s.

    Tenant requests share their tenant's ``prefix_len``-token system
    prompt VERBATIM (the radix cache matches on content), followed by a
    private body; fresh requests (tenant -1) are fully random. Returns
    ``(arrivals, reused_flags)`` so callers can compute the reuse
    ceiling the cache is measured against."""
    from repro.serving import Arrival, SamplingParams
    prefixes = tenant_prompts(n_tenants, prefix_len, vocab_size, seed)
    rng = np.random.default_rng(seed * 31 + 1)
    arrivals, reused = [], []
    for r in reqs:
        body = rng.integers(0, vocab_size,
                            size=min(r.body_len, max_body)).tolist()
        if r.tenant >= 0:
            prompt = prefixes[r.tenant % n_tenants] + body
        else:
            prompt = rng.integers(0, vocab_size,
                                  size=prefix_len).tolist() + body
        arrivals.append(Arrival(
            at=r.arrival * time_scale, prompt=prompt,
            sampling=SamplingParams(max_new_tokens=r.output_len)))
        reused.append(r.tenant >= 0)
    return arrivals, reused


# --- overload traces (bursty / diurnal arrival processes) --------------- #
def gen_bursty_trace(n: int, base_rate: float, *, burst_factor: float = 6.0,
                     burst_p: float = 0.15, mean_dwell: int = 8,
                     prompt_len: int = 12, output_len: int = 8,
                     seed: int = 0) -> List[TraceRequest]:
    """Markov-modulated (MMPP) arrival stream for overload evaluation.

    A two-state Markov chain modulates the Poisson rate: the CALM state
    emits at ``base_rate`` req/s, the BURST state at ``base_rate *
    burst_factor``; each arrival flips the state with the hazard implied
    by ``burst_p`` (long-run burst fraction) and ``mean_dwell``
    (arrivals per state visit). Sustained-overload evaluation drives
    this at a rate the cluster cannot absorb, so survival — not raw
    throughput — is what differentiates schedulers. Lengths are fixed
    (``prompt_len``/``output_len``) so capacity pressure comes purely
    from the arrival process."""
    rng = np.random.default_rng(seed * 7919 + 101)
    # Dwell hazards from the stationary split: leave each state after a
    # geometric number of arrivals with the given mean dwell.
    p_leave_calm = burst_p / max(1e-9, (1 - burst_p)) / mean_dwell
    p_leave_burst = 1.0 / mean_dwell
    t, state, out = 0.0, 0, []
    for _ in range(n):
        rate = base_rate * (burst_factor if state else 1.0)
        t += rng.exponential(1.0 / rate)
        out.append(TraceRequest(t, prompt_len, output_len))
        if rng.random() < (p_leave_burst if state else p_leave_calm):
            state = 1 - state
    return out


def gen_diurnal_trace(n: int, base_rate: float, *, peak_factor: float = 4.0,
                      period_s: float = 60.0, prompt_len: int = 12,
                      output_len: int = 8, seed: int = 0
                      ) -> List[TraceRequest]:
    """Sinusoidal (diurnal) arrival stream: the rate swings between
    ``base_rate`` and ``base_rate * peak_factor`` over ``period_s``
    (a compressed day). Generated by thinning a Poisson stream at the
    peak rate, so inter-arrival statistics are exact."""
    rng = np.random.default_rng(seed * 7919 + 211)
    peak = base_rate * peak_factor
    t, out = 0.0, []
    while len(out) < n:
        t += rng.exponential(1.0 / peak)
        phase = 0.5 - 0.5 * np.cos(2 * np.pi * t / period_s)
        rate = base_rate + (peak - base_rate) * phase
        if rng.random() < rate / peak:       # thinning acceptance
            out.append(TraceRequest(t, prompt_len, output_len))
    return out


def overload_arrivals(reqs: List[TraceRequest], vocab_size: int, *,
                      deadline_p: float = 0.5, deadline_s: float = 2.0,
                      priority: int = 1, seed: int = 0,
                      time_scale: float = 1.0):
    """Materialize an overload trace as SLO-carrying ``Arrival``s.

    A ``deadline_p`` fraction of arrivals are latency-critical: they
    carry ``deadline_s`` (seconds after arrival) and ``priority``; the
    rest are best-effort (no deadline, priority 0) — the victims the
    SLO-aware preemptor is expected to pause first. Returns
    ``(arrivals, critical_flags)``."""
    from repro.serving import Arrival, SamplingParams
    rng = np.random.default_rng(seed * 31 + 3)
    arrivals, critical = [], []
    for r in reqs:
        crit = bool(rng.random() < deadline_p)
        arrivals.append(Arrival(
            at=r.arrival * time_scale,
            prompt=rng.integers(0, vocab_size,
                                size=r.prompt_len).tolist(),
            sampling=SamplingParams(max_new_tokens=r.output_len),
            priority=priority if crit else 0,
            deadline_s=deadline_s if crit else None))
        critical.append(crit)
    return arrivals, critical


def to_arrivals(reqs: List[TraceRequest], vocab_size: int, seed: int = 0,
                prompt_scale: float = 1.0, max_prompt: int = 10 ** 9,
                max_output: int = 10 ** 9, time_scale: float = 1.0):
    """Wire a generated trace into the ``LLMServer.run`` open-loop pump.

    Materializes each ``TraceRequest`` as a ``serving.Arrival`` with
    random token ids. ``prompt_scale``/``max_prompt``/``max_output``
    shrink the paper-scale lengths to what a smoke model can serve in
    CI; ``time_scale`` compresses the arrival timeline the same way.
    """
    from repro.serving import Arrival, SamplingParams
    rng = np.random.default_rng(seed)
    out = []
    for r in reqs:
        plen = max(1, min(int(r.prompt_len * prompt_scale), max_prompt))
        olen = max(1, min(r.output_len, max_output))
        out.append(Arrival(
            at=r.arrival * time_scale,
            prompt=rng.integers(0, vocab_size, size=plen).tolist(),
            sampling=SamplingParams(max_new_tokens=olen)))
    return out
