"""Paper Fig. 11: DistAttention vs RingAttention vs head-TP (4-way).

Two measurements per method at LLaMA2-13B-class dims (nemo-12B config),
context 4K..256K on 4 ranks:
  (1) bytes moved per decode step — exact, from the algorithm;
  (2) modeled step time on v5e (compute bandwidth + interconnect),
plus a REAL wall-clock comparison of the three shard_map kernels on 4
fake CPU devices at a reduced size (collectives execute, compute real).
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

from repro.configs import get_config
from repro.distributed.hardware import V5E

try:
    from benchmarks.benchjson import write_bench_json
except ImportError:                      # run as a script from benchmarks/
    from benchjson import write_bench_json

RANKS = 4


def modeled(csv=True):
    cfg = get_config("mistral-nemo-12b")
    kvb = cfg.kv_bytes_per_token()                     # all layers
    rows = []
    for ctx in (4096, 16384, 65536, 262144):
        kv_total = ctx * kvb
        # DistAttention: q + merge partials per layer per rank.
        q = (cfg.num_heads * cfg.head_dim * 2 +
             cfg.num_heads * cfg.head_dim * 4 + 2 * cfg.num_heads * 4) \
            * cfg.num_layers * (RANKS - 1)
        # RingAttention (decode): KV blocks rotate through all ranks
        # every step: each rank ships its kv shard (RANKS-1) times.
        ring = kv_total * (RANKS - 1) / RANKS * (RANKS - 1)
        # TP by heads: KV static, but activations all-reduce per layer
        # (2 all-reduces of [1, d]) — plus kv-head replication memory.
        tp = 2 * 2 * cfg.d_model * 2 * (RANKS - 1) / RANKS \
            * cfg.num_layers
        t_mem = kv_total / (V5E.hbm_bw * RANKS)        # shared by all
        rows.append((ctx,
                     q, t_mem + q / V5E.ici_link_bw,
                     ring, t_mem + ring / V5E.ici_link_bw,
                     tp, t_mem + tp / V5E.ici_link_bw))
    if csv:
        print("fig11_ctx,dist_bytes,dist_t,ring_bytes,ring_t,"
              "tp_bytes,tp_t")
        for r in rows:
            print(f"{r[0]},{r[1]:.3e},{r[2]:.3e},{r[3]:.3e},{r[4]:.3e},"
                  f"{r[5]:.3e},{r[6]:.3e}")
    return rows


_WALL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, time
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.baselines import distattn_decode, ship_kv_decode, \
    tp_head_attention_decode

mesh = jax.make_mesh((4,), ("x",))
B, H, K, D, S = 4, 8, 8, 64, 8192
key = jax.random.PRNGKey(0)
q = jax.random.normal(key, (B, H, D), jnp.float32)
k = jax.random.normal(key, (B, S, K, D), jnp.float32)
v = jax.random.normal(key, (B, S, K, D), jnp.float32)
mask = jnp.ones((B, S), bool)

dist = jax.jit(jax.shard_map(
    lambda q, k, v, m: distattn_decode(q, k, v, m, "x"),
    mesh=mesh, in_specs=(P(), P(None, "x"), P(None, "x"), P(None, "x")),
    out_specs=P(), check_vma=False))
ship = jax.jit(jax.shard_map(
    lambda q, k, v, m: ship_kv_decode(q, k, v, m, "x"),
    mesh=mesh, in_specs=(P(), P(None, "x"), P(None, "x"), P(None, "x")),
    out_specs=P(), check_vma=False))
tp = jax.jit(jax.shard_map(
    lambda q, k, v, m: tp_head_attention_decode(q, k, v, m),
    mesh=mesh, in_specs=(P(None, "x"), P(None, None, "x"),
                         P(None, None, "x"), P()),
    out_specs=P(None, "x"), check_vma=False))

with mesh:
    o1 = dist(q, k, v, mask); o2 = ship(q, k, v, mask)
    o3 = tp(q, k, v, mask)
np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), atol=1e-4)

def timeit(f, *a):
    f(*a)[0].block_until_ready() if isinstance(f(*a), tuple) else \
        f(*a).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        r = f(*a)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / 20 * 1e6

with mesh:
    print(f"WALL,dist={timeit(dist,q,k,v,mask):.0f},"
          f"ship={timeit(ship,q,k,v,mask):.0f},"
          f"tp={timeit(tp,q,k,v,mask):.0f}")
"""


def wall_clock():
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _WALL_SCRIPT, src],
                       capture_output=True, text=True, timeout=900,
                       env=env)
    for line in r.stdout.splitlines():
        if line.startswith("WALL"):
            print("fig11_wallclock_us_cpu4dev," + line[5:])
            return line
    print("fig11_wallclock_us_cpu4dev,FAILED", r.stderr[-400:])
    return None


def main():
    t0 = time.perf_counter()
    rows = modeled()
    wall_clock()
    us = (time.perf_counter() - t0) * 1e6
    r = rows[-1]
    print(f"bench_distattn_methods,{us:.1f},"
          f"ring_over_dist_bytes_262k={r[3] / r[1]:.0f}x")
    write_bench_json(
        "distattn_methods", rows=rows,
        config={"model": "mistral-nemo-12b", "ranks": RANKS},
        header=["ctx", "dist_bytes", "dist_t", "ring_bytes", "ring_t",
                "tp_bytes", "tp_t"],
        metrics={"ring_over_dist_bytes_262k": r[3] / r[1]})


if __name__ == "__main__":
    main()
