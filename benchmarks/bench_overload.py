"""Overload-survival benchmark (ISSUE 8 acceptance gates).

Two measured sections on a real smoke-scale ``LLMServer``:

  * deadline goodput under ~2x sustained overload — a bursty (MMPP)
    arrival trace mixing best-effort long decodes with deadline-carrying
    critical shorts is served twice on identical configs: once with the
    admission queue only (``overload.enabled=False`` — the queue/reject
    baseline) and once with preemptive pause/host-spill scheduling.
    Critical arrivals can only meet their deadlines by pausing running
    best-effort victims, so the preemptive run's on-time finishes must
    be >= 1.3x the baseline's (gated as ``goodput_ratio_ok``).
  * preempted token identity — a background request is forcibly paused
    (its KV chain spilled to the pinned preempt tier) and resumed, and
    its final output is compared token-for-token against an unpreempted
    oracle server on the same prompt, in BOTH pool modes (gated as
    ``preempt_token_identity``).

Deadlines are calibrated against the measured decode step time so the
gate tracks scheduling behavior, not machine speed.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serving import LLMServer, ServingConfig
from repro.serving.config import OverloadPolicy
from repro.serving.request import SamplingParams

try:
    from benchmarks.benchjson import write_bench_json
    from benchmarks.traces import gen_bursty_trace, overload_arrivals
except ImportError:                      # run as a script from benchmarks/
    from benchjson import write_bench_json
    from traces import gen_bursty_trace, overload_arrivals

N_REQ = 14               # bursty trace length (CI-smoke sized)
DEADLINE_P = 0.5         # fraction of arrivals that carry a deadline
BG_TOKENS = 64           # best-effort decode length (the slot hogs)
CRIT_TOKENS = 4          # critical decode length
PROMPT_LEN = 12


def _server(params, cfg, *, preempt, global_pool=False, **over):
    policy = OverloadPolicy(enabled=preempt, victim_min_slack_s=0.0)
    base = dict(n_instances=1, max_batch=2, max_local_len=128,
                overload=policy, global_pool=global_pool)
    base.update(over)
    return LLMServer(params, cfg, ServingConfig.smoke(**base))


def _calibrate_step_s(params, cfg) -> float:
    """Measured per-step wall time of a warm 2-slot decode."""
    srv = _server(params, cfg, preempt=False)
    rng = np.random.default_rng(7)
    for _ in range(2):
        srv.submit(rng.integers(0, cfg.vocab_size, PROMPT_LEN).tolist(),
                   SamplingParams(max_new_tokens=24))
    srv.step()                           # pays compile
    t0 = time.perf_counter()
    n = 12
    for _ in range(n):
        srv.step()
    dt = (time.perf_counter() - t0) / n
    srv.drain()
    return dt


def run_goodput(params, cfg, csv=True):
    """Bursty 2x-overload trace: preemptive vs queue-only goodput."""
    step_s = _calibrate_step_s(params, cfg)
    # Capacity: 2 slots, each best-effort request holds one for
    # ~BG_TOKENS steps. 2x overload: arrivals at twice the rate the
    # slots can drain the MIX's mean service time.
    mean_service = (DEADLINE_P * CRIT_TOKENS
                    + (1 - DEADLINE_P) * BG_TOKENS) * step_s
    rate = 2.0 * 2 / mean_service
    trace = gen_bursty_trace(N_REQ, rate, burst_factor=6.0,
                             prompt_len=PROMPT_LEN, seed=5)
    # Critical deadline: comfortably above the whole critical burst's
    # service time (prefill + CRIT_TOKENS steps each, two slots, plus a
    # preemption round) but well below a best-effort residency
    # (BG_TOKENS steps) — only preemption can meet it from a full batch.
    deadline_s = 30 * step_s

    def materialize():
        arrivals, critical = overload_arrivals(
            trace, cfg.vocab_size, deadline_p=DEADLINE_P,
            deadline_s=deadline_s, seed=5)
        for a, crit in zip(arrivals, critical):
            a.sampling = SamplingParams(
                max_new_tokens=CRIT_TOKENS if crit else BG_TOKENS)
        return arrivals

    results = {}
    for mode in ("baseline", "preempt"):
        srv = _server(params, cfg, preempt=(mode == "preempt"))
        # Warm the compile cache outside the measured trace.
        srv.submit([1] * PROMPT_LEN,
                   SamplingParams(max_new_tokens=2)).result()
        stats = srv.run(materialize())
        stats["preemptions"] = srv.metrics["preemptions"]
        stats["arrival_rate_hz_est"] = srv.metrics["arrival_rate_hz"]
        results[mode] = stats

    n = results["preempt"]["n_requests"]
    good_on = results["preempt"]["deadline_goodput"] * n
    good_off = results["baseline"]["deadline_goodput"] * n
    ratio = good_on / max(good_off, 1.0)
    if csv:
        print("goodput_metric,baseline,preempt")
        for k in ("deadline_goodput", "slo_attainment", "deadline_missed",
                  "finished", "preemptions", "throughput_tok_s"):
            print(f"{k},{results['baseline'][k]:.3f},"
                  f"{results['preempt'][k]:.3f}")
        print(f"step_s,{step_s * 1e3:.2f}ms,")
        print(f"goodput_ratio,{ratio:.2f},")
    return dict(ratio=ratio, step_s=step_s,
                on=results["preempt"], off=results["baseline"])


def run_identity(params, cfg, global_pool, csv=True):
    """Pause/spill/resume a request and diff it against an unpreempted
    oracle server on the same prompt (byte-identical KV <=> identical
    greedy tokens)."""
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, PROMPT_LEN).tolist()
    sp = SamplingParams(max_new_tokens=20)

    oracle = _server(params, cfg, preempt=False, global_pool=global_pool,
                     max_batch=1).submit(prompt, sp).result()

    srv = _server(params, cfg, preempt=True, global_pool=global_pool,
                  max_batch=1)
    h = srv.submit(prompt, sp)
    for _ in range(6):
        srv.step()
    pre = srv.cluster.preemptor
    assert pre.pause(h._req), "forced pause refused"
    out = h.result()
    assert pre.stats.preemptions == 1 and pre.stats.resumes == 1
    identical = out == oracle
    mode = "global" if global_pool else "local"
    if csv:
        print(f"identity_{mode},preemptions="
              f"{pre.stats.preemptions},identical={int(identical)}")
    return float(identical)


def main():
    t0 = time.perf_counter()
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    gp = run_goodput(params, cfg)
    ident_local = run_identity(params, cfg, global_pool=False)
    ident_global = run_identity(params, cfg, global_pool=True)
    identity = ident_local * ident_global
    us = (time.perf_counter() - t0) * 1e6
    print(f"bench_overload,{us:.1f},goodput_ratio={gp['ratio']:.2f}x,"
          f"identity={identity:.0f}")
    write_bench_json(
        "overload",
        rows=[["goodput", gp["off"]["deadline_goodput"],
               gp["on"]["deadline_goodput"], gp["ratio"],
               gp["on"]["preemptions"]],
              ["identity", ident_local, ident_global, identity, 0.0]],
        config={"model": "olmo-1b-smoke", "n_req": N_REQ,
                "deadline_p": DEADLINE_P, "bg_tokens": BG_TOKENS,
                "crit_tokens": CRIT_TOKENS,
                "step_s": gp["step_s"]},
        header=["section", "a", "b", "c", "d"],
        metrics={
            # All gated metrics are higher-is-better.
            "goodput_ratio": gp["ratio"],
            # Hard gate on the >= 1.3x acceptance bound.
            "goodput_ratio_ok": float(gp["ratio"] >= 1.3),
            "preempt_token_identity": identity,
        })


if __name__ == "__main__":
    main()
