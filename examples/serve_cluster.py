"""End-to-end driver: a 3-instance cluster behind the LLMServer
frontend — mixed short/long traffic with priorities and a deadline,
DistAttention spanning, a cancellation, and elastic scale-out.

    PYTHONPATH=src python examples/serve_cluster.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serving import (LLMServer, RequestState, SamplingParams,
                           ServingConfig)


def main():
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = LLMServer(params, cfg, ServingConfig.smoke(n_instances=3))

    # Mixed load: mostly short chats + one long-context request that
    # overflows its instance and spans creditors via DistAttention. The
    # long request carries a deadline, so the planner treats it as the
    # most urgent debtor when offloading prefix blocks.
    rng = np.random.default_rng(7)
    handles = []
    for i, n in enumerate((6, 9, 60, 12, 7, 15)):
        handles.append(server.submit(
            rng.integers(0, cfg.vocab_size, size=n).tolist(),
            SamplingParams(max_new_tokens=10),
            priority=1 if n > 30 else 0,
            deadline_s=30.0 if n > 30 else None))
    victim = handles[1]                   # running by the time we cancel

    step = 0
    while not all(h.done for h in handles) and step < 200:
        made = server.step()
        step += 1
        if step % 5 == 0:
            views = {i: (e.batch_size,
                         f"{e.rmanager.pool.memory_utilization:.0%}")
                     for i, e in server.cluster.engines.items()
                     if i not in server.cluster._dead}
            print(f"step {step:03d}: +{made} tok  "
                  f"(inst -> batch, mem_util) {views}")
        if step == 8:
            print(f">>> cancelling req {victim.req_id} mid-flight")
            victim.cancel()
        if step == 12:
            print(">>> elastic scale-out: adding instance")
            server.cluster.add_instance(params)

    stats = server.cluster.throughput_stats
    print(f"\nKV moved: {stats['kv_moved_bytes'] / 1024:.1f} KiB; "
          f"query/merge traffic: "
          f"{stats['query_shipped_bytes'] / 1024:.1f} KiB")
    for h in handles:
        m = h.metrics
        print(f"  [{h.status.value:9s}] req {h.req_id} "
              f"out={int(m['n_tokens'])} ttft={m['ttft'] * 1e3:.0f}ms")
    assert victim.status == RequestState.CANCELLED
    assert all(h.status == RequestState.FINISHED
               for h in handles if h is not victim)
    print("all surviving requests served; cancellation released its KV.")


if __name__ == "__main__":
    main()
