"""End-to-end driver: a 3-instance cluster with gManager scheduling,
mixed short/long traffic, DistAttention spanning, a mid-run instance
failure, and elastic scale-out.

    PYTHONPATH=src python examples/serve_cluster.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serving import Cluster, Request, RequestState, SamplingParams


def main():
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    cluster = Cluster(params, cfg, n_instances=3, max_batch=3,
                      max_local_len=32, pool_blocks=48, block_size=8,
                      move_chunk_tokens=8, heartbeat_timeout=1e9)
    rng = np.random.default_rng(7)

    # Mixed load: mostly short chats + one long-context request that
    # overflows its instance and spans creditors via DistAttention.
    reqs = []
    for i, n in enumerate((6, 9, 60, 12, 7, 15)):
        reqs.append(Request(
            prompt=list(rng.integers(0, cfg.vocab_size, size=n)),
            sampling=SamplingParams(max_new_tokens=10)))
    for r in reqs:
        cluster.submit(r)

    for step in range(1, 200):
        made = cluster.step()
        if step % 5 == 0:
            views = {i: (e.batch_size,
                         f"{e.rmanager.pool.memory_utilization:.0%}")
                     for i, e in cluster.engines.items()
                     if i not in cluster._dead}
            print(f"step {step:03d}: +{made} tok  "
                  f"(inst -> batch, mem_util) {views}")
        if step == 12:
            print(">>> elastic scale-out: adding instance")
            cluster.add_instance(params)
        if all(r.done for r in reqs):
            break

    stats = cluster.throughput_stats
    print(f"\nKV moved: {stats['kv_moved_bytes'] / 1024:.1f} KiB; "
          f"query/merge traffic: "
          f"{stats['query_shipped_bytes'] / 1024:.1f} KiB")
    for r in reqs:
        status = "OK " if r.state == RequestState.FINISHED else "FAIL"
        print(f"  [{status}] req {r.req_id} len={r.length} "
              f"out={len(r.output)}")
    assert all(r.state == RequestState.FINISHED for r in reqs)
    print("all requests served.")


if __name__ == "__main__":
    main()
