"""End-to-end driver: a 3-instance cluster behind the LLMServer
frontend — mixed short/long traffic with priorities and a deadline,
DistAttention spanning, a cancellation, and elastic scale-out —
followed by an overload-survival demo (bursty arrivals force the
preemptor to pause a best-effort request for a deadline-urgent one).

    PYTHONPATH=src python examples/serve_cluster.py

``--chaos`` runs the fault-tolerance demo instead: an instance is
killed mid-decode and the cluster detects it, quarantines the rank,
and replays the affected request to an identical token stream.

    PYTHONPATH=src python examples/serve_cluster.py --chaos
"""
import sys

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serving import (LLMServer, RequestState, SamplingParams,
                           ServingConfig)
from repro.serving.config import FaultPolicy, OverloadPolicy


def main():
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = LLMServer(params, cfg, ServingConfig.smoke(n_instances=3))

    # Mixed load: mostly short chats + one long-context request that
    # overflows its instance and spans creditors via DistAttention. The
    # long request carries a deadline, so the planner treats it as the
    # most urgent debtor when offloading prefix blocks.
    rng = np.random.default_rng(7)
    handles = []
    for i, n in enumerate((6, 9, 60, 12, 7, 15)):
        handles.append(server.submit(
            rng.integers(0, cfg.vocab_size, size=n).tolist(),
            SamplingParams(max_new_tokens=10),
            priority=1 if n > 30 else 0,
            deadline_s=30.0 if n > 30 else None))
    victim = handles[1]                   # running by the time we cancel

    step = 0
    while not all(h.done for h in handles) and step < 200:
        made = server.step()
        step += 1
        if step % 5 == 0:
            views = {i: (e.batch_size,
                         f"{e.rmanager.pool.memory_utilization:.0%}")
                     for i, e in server.cluster.engines.items()
                     if i not in server.cluster._dead}
            print(f"step {step:03d}: +{made} tok  "
                  f"(inst -> batch, mem_util) {views}")
        if step == 8:
            print(f">>> cancelling req {victim.req_id} mid-flight")
            victim.cancel()
        if step == 12:
            print(">>> elastic scale-out: adding instance")
            server.cluster.add_instance(params)

    stats = server.cluster.throughput_stats
    print(f"\nKV moved: {stats['kv_moved_bytes'] / 1024:.1f} KiB; "
          f"query/merge traffic: "
          f"{stats['query_shipped_bytes'] / 1024:.1f} KiB")
    for h in handles:
        m = h.metrics
        print(f"  [{h.status.value:9s}] req {h.req_id} "
              f"out={int(m['n_tokens'])} ttft={m['ttft'] * 1e3:.0f}ms")
    assert victim.status == RequestState.CANCELLED
    assert all(h.status == RequestState.FINISHED
               for h in handles if h is not victim)
    print("all surviving requests served; cancellation released its KV.")

    overload_demo(params, cfg)


def overload_demo(params, cfg):
    """Overload survival: a one-slot instance is hogged by a best-effort
    long decode when a burst of deadline-urgent shorts arrives. With
    ``OverloadPolicy(enabled=True)`` the server pauses the victim at a
    step boundary (KV chain spilled byte-for-byte to the pinned host
    tier), serves the urgent burst, then resumes the victim with tokens
    identical to an undisturbed run — all visible in ``server.metrics``.
    """
    print("\n--- overload survival demo (preemptive pause/resume) ---")
    server = LLMServer(params, cfg, ServingConfig.smoke(
        n_instances=1, max_batch=1, max_local_len=128,
        overload=OverloadPolicy(enabled=True, victim_min_slack_s=0.0)))
    rng = np.random.default_rng(13)

    bg = server.submit(rng.integers(0, cfg.vocab_size, 12).tolist(),
                       SamplingParams(max_new_tokens=48))
    for _ in range(4):                    # let the hog get established
        server.step()

    # A bursty spike of latency-critical arrivals: none can be admitted
    # (the slot is taken), so the SLO-aware preemptor pauses the
    # best-effort victim — its slack is infinite, theirs is not.
    urgent = [server.submit(rng.integers(0, cfg.vocab_size, 8).tolist(),
                            SamplingParams(max_new_tokens=4),
                            priority=1, deadline_s=30.0)
              for _ in range(2)]
    while not all(h.done for h in urgent):
        server.step()
        m = server.metrics
        if m["paused_now"]:
            print(f"  victim req {bg.req_id} PAUSED "
                  f"(preempt tier holds "
                  f"{m['preempt_tier_blocks_used']:.0f} KV frames)")

    out = bg.result()                     # drives the resume path
    m = server.metrics
    print(f"  urgent burst served on time: "
          f"{[h.status.value for h in urgent]}")
    print(f"  victim resumed and finished: {len(out)} tokens, "
          f"preemptions={m['preemptions']:.0f} "
          f"resumes={m['preempt_resumes']:.0f} "
          f"est arrival rate={m['arrival_rate_hz']:.2f}/s")
    assert bg.status == RequestState.FINISHED
    assert m["preemptions"] >= 1 and m["paused_now"] == 0
    print("overload survived: victim paused, spilled, resumed intact.")


def chaos_demo():
    """Fault tolerance: kill an instance mid-decode and watch detection,
    quarantine, and deterministic token-replay recovery.

    An oracle server (no fault) first records the greedy stream for the
    same prompt; then a second server loses the instance serving the
    request and must reproduce that stream exactly — the replay
    re-prefills the already-emitted tokens, so nothing is resampled.
    """
    print("--- chaos demo (crash detection + token-replay recovery) ---")
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    serving = dict(n_instances=3, max_batch=2, heartbeat_timeout=0.0,
                   faults=FaultPolicy(max_transfer_retries=2))
    rng = np.random.default_rng(42)
    prompt = rng.integers(0, cfg.vocab_size, 12).tolist()
    sp = SamplingParams(max_new_tokens=12)

    oracle = LLMServer(params, cfg, ServingConfig.smoke(**serving))
    ref = oracle.submit(prompt, sp).result()

    server = LLMServer(params, cfg, ServingConfig.smoke(**serving))
    h = server.submit(prompt, sp)
    while len(h._req.output) < 4:         # mid-decode
        server.step()
    cl = server.cluster
    victim = next(i for i, e in cl.engines.items()
                  if h.req_id in e.rmanager.pool.requests)
    print(f">>> killing instance {victim} (serves req {h.req_id}, "
          f"{len(h._req.output)} tokens emitted)")
    cl.kill_instance(victim)
    out = h.result()

    m = server.metrics
    print(f"  dead instances: {m['dead_instances']:.0f}  "
          f"recoveries: {m['fault_recoveries']:.0f}  "
          f"replayed tokens: {m['replayed_tokens']:.0f}")
    print(f"  oracle: {ref}\n  replay: {out}")
    assert h.status == RequestState.FINISHED
    assert out == ref and m["fault_recoveries"] == 1
    print("crash survived: rank quarantined, request replayed, "
          "stream byte-identical.")


if __name__ == "__main__":
    if "--chaos" in sys.argv:
        chaos_demo()
    else:
        main()
