"""Quickstart: build a tiny model and serve requests through the
request-lifecycle frontend — submit, stream tokens, read metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serving import LLMServer, SamplingParams, ServingConfig


def main():
    cfg = get_smoke_config("qwen3-0.6b")
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.2f}M params, "
          f"family={cfg.family})")
    params = init_params(jax.random.PRNGKey(0), cfg)

    server = LLMServer(params, cfg,
                       ServingConfig.smoke(n_instances=1, max_batch=4,
                                           max_local_len=64,
                                           pool_blocks=64))
    rng = np.random.default_rng(0)
    handles = [server.submit(
        rng.integers(0, cfg.vocab_size, size=n).tolist(),
        SamplingParams(max_new_tokens=12, temperature=0.8, top_k=20,
                       seed=i))
        for i, n in enumerate((6, 11, 17))]

    # Stream the first request token-by-token; the iterator drives the
    # server, so the other handles make progress concurrently.
    print(f"req {handles[0].req_id} streaming:", end=" ", flush=True)
    for tok in handles[0].tokens():
        print(tok, end=" ", flush=True)
    print()

    for h in handles:
        out = h.result()
        m = h.metrics
        print(f"req {h.req_id}: {h.status.value}, {len(out)} tokens, "
              f"ttft={m['ttft'] * 1e3:.1f}ms "
              f"tbt_mean={m['tbt_mean'] * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
