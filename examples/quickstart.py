"""Quickstart: build a tiny model and serve requests through the
request-lifecycle frontend — submit, stream tokens, read metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serving import LLMServer, SamplingParams, ServingConfig


def main():
    cfg = get_smoke_config("qwen3-0.6b")
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.2f}M params, "
          f"family={cfg.family})")
    params = init_params(jax.random.PRNGKey(0), cfg)

    server = LLMServer(params, cfg,
                       ServingConfig.smoke(n_instances=1, max_batch=4,
                                           max_local_len=64,
                                           pool_blocks=64))
    rng = np.random.default_rng(0)
    handles = [server.submit(
        rng.integers(0, cfg.vocab_size, size=n).tolist(),
        SamplingParams(max_new_tokens=12, temperature=0.8, top_k=20,
                       seed=i))
        for i, n in enumerate((6, 11, 17))]

    # Stream the first request token-by-token; the iterator drives the
    # server, so the other handles make progress concurrently.
    print(f"req {handles[0].req_id} streaming:", end=" ", flush=True)
    for tok in handles[0].tokens():
        print(tok, end=" ", flush=True)
    print()

    for h in handles:
        out = h.result()
        m = h.metrics
        print(f"req {h.req_id}: {h.status.value}, {len(out)} tokens, "
              f"ttft={m['ttft'] * 1e3:.1f}ms "
              f"tbt_mean={m['tbt_mean'] * 1e3:.1f}ms")

    prefix_caching_demo(params, cfg)


def prefix_caching_demo(params, cfg):
    """Cross-request prefix caching + the host-DRAM KV tier: finished
    requests' KV blocks are kept (device first, spilling to host under
    pressure) in a radix cache keyed by token content, so a request
    sharing a prompt prefix — a system prompt, a few-shot template,
    multi-turn history — skips prefill for the cached part entirely.
    Tokens are bit-identical to a cold run; only TTFT changes."""
    server = LLMServer(params, cfg,
                       ServingConfig.smoke(n_instances=1, max_batch=4,
                                           max_local_len=64,
                                           pool_blocks=64,
                                           prefix_cache=True,
                                           host_tier_blocks=256))
    rng = np.random.default_rng(1)
    system_prompt = rng.integers(0, cfg.vocab_size, size=24).tolist()
    sp = SamplingParams(max_new_tokens=8)

    cold = server.submit(system_prompt, sp)
    cold.result()
    warm = server.submit(system_prompt, sp)     # full-prompt cache hit
    warm.result()
    m = server.metrics
    print(f"prefix cache: cold ttft={cold.metrics['ttft'] * 1e3:.1f}ms, "
          f"warm ttft={warm.metrics['ttft'] * 1e3:.1f}ms, "
          f"hit_tokens={m['cache_hit_tokens']}, "
          f"cached_blocks={m['cache_device_blocks']}")


if __name__ == "__main__":
    main()
