"""Quickstart: build a tiny model and serve a few batched requests.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serving import InstanceEngine, Request, SamplingParams


def main():
    cfg = get_smoke_config("qwen3-0.6b")
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.2f}M params, "
          f"family={cfg.family})")
    params = init_params(jax.random.PRNGKey(0), cfg)

    engine = InstanceEngine(params, cfg, max_batch=4, max_local_len=64,
                            pool_blocks=64, block_size=8)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(0, cfg.vocab_size, size=n)),
                    sampling=SamplingParams(max_new_tokens=12,
                                            temperature=0.8, seed=i))
            for i, n in enumerate((6, 11, 17))]
    for r in reqs:
        engine.submit(r)

    step = 0
    while not all(r.done for r in reqs) and step < 64:
        made = engine.step()
        step += 1
        print(f"step {step:02d}: batch={engine.batch_size} "
              f"+{made} tokens")
    for r in reqs:
        print(f"req {r.req_id}: prompt[{len(r.prompt)}] -> "
              f"output {r.output}")


if __name__ == "__main__":
    main()
