"""Long-context serving: a prompt far beyond any single instance's memory
is served by pooling KV across the whole cluster (the paper's headline
2000K-on-32-GPUs scenario, at CPU-smoke scale).

Verifies the DistAttention output is IDENTICAL to a single big cache.

    PYTHONPATH=src python examples/long_context.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import decode_step, init_params
from repro.models.prefill import prefill
from repro.serving import (LLMServer, RequestState, SamplingParams,
                           ServingConfig)


def reference(params, cfg, prompt, n_new):
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
    return out


def main():
    cfg = get_smoke_config("mistral-nemo-12b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)

    # Each instance holds <=24 local tokens; the prompt is 100.
    prompt = list(rng.integers(0, cfg.vocab_size, size=100))
    n_new = 12
    print(f"prompt len {len(prompt)}; per-instance local window 24 "
          f"-> needs cluster pooling")

    server = LLMServer(params, cfg,
                       ServingConfig.smoke(n_instances=6, max_batch=2,
                                           max_local_len=24,
                                           pool_blocks=32))
    handle = server.submit(prompt, SamplingParams(max_new_tokens=n_new))
    out = handle.result(max_steps=300)
    assert handle.status == RequestState.FINISHED, handle.status

    ref = reference(params, cfg, prompt, n_new)
    match = out == ref
    print(f"spanned output: {out}")
    print(f"reference:      {ref}")
    print(f"exact match: {match}")
    spans = {i: e.rmanager.pool.alloc.used_count
             for i, e in server.cluster.engines.items()}
    print(f"blocks held per instance at finish: {spans}")
    assert match
    print("long-context DistAttention == single-cache reference.")


if __name__ == "__main__":
    main()
