"""Train a ~100M-param dense model for a few hundred steps on CPU with
checkpoint/restart in the middle (fault-tolerance demo).

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer, restore_train_state, \
    save_train_state
from repro.configs import get_config
from repro.models.model import init_params
from repro.training.data import DataConfig, batch_for_step
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import (TrainConfig, init_train_state,
                                       train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    # ~100M params: a slimmed qwen3-0.6b (fewer layers, smaller vocab).
    cfg = dataclasses.replace(get_config("qwen3-0.6b"), num_layers=8,
                              vocab_size=8192, name="qwen3-100m")
    print(f"{cfg.name}: {cfg.param_count() / 1e6:.1f}M params")

    params = init_params(jax.random.PRNGKey(0), cfg)
    acfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    tcfg = TrainConfig(remat=True, microbatches=1)
    state = init_train_state(params, acfg, tcfg)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                    global_batch=4, seed=0)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)

    step_fn = jax.jit(lambda s, t, m: train_step(s, t, m, cfg=cfg,
                                                 tcfg=tcfg, adam_cfg=acfg))
    half = args.steps // 2
    t0 = time.time()
    for step in range(half):
        toks, mask = batch_for_step(dc, step)
        state, out = step_fn(state, jnp.asarray(toks), jnp.asarray(mask))
        if step % 20 == 0:
            print(f"step {step:4d} loss {float(out['loss']):.4f} "
                  f"({(time.time() - t0):.0f}s)")
    save_train_state(ckpt, half - 1, state)
    print(f">>> checkpoint @ step {half - 1}; simulating restart")

    # "Crash" — rebuild everything from disk and resume.
    like = init_train_state(init_params(jax.random.PRNGKey(0), cfg),
                            acfg, tcfg)
    state = restore_train_state(ckpt, ckpt.latest(), like)
    for step in range(half, args.steps):
        toks, mask = batch_for_step(dc, step)
        state, out = step_fn(state, jnp.asarray(toks), jnp.asarray(mask))
        if step % 20 == 0:
            print(f"step {step:4d} loss {float(out['loss']):.4f}")
    print(f"final loss {float(out['loss']):.4f} "
          f"(random-chance {jnp.log(cfg.vocab_size):.2f})")


if __name__ == "__main__":
    main()
