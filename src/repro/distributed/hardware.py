"""Target hardware constants (TPU v5e) for perf modeling and roofline."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12       # FLOP/s per chip
    hbm_bytes: float = 16e9               # per chip
    hbm_bw: float = 819e9                 # bytes/s per chip
    ici_link_bw: float = 50e9             # bytes/s per link
    dcn_bw: float = 25e9                  # bytes/s per host, pod-to-pod
    vmem_bytes: float = 128e6             # ~128 MB VMEM per chip
    # Device <-> host-DRAM transfer bandwidth (PCIe-class): what a KV
    # block pays to spill to or prefetch from the host tier.
    host_link_bw: float = 32e9            # bytes/s per chip

    @property
    def critical_intensity(self) -> float:
        """FLOP/byte where compute and HBM time are equal (~240 on v5e)."""
        return self.peak_flops_bf16 / self.hbm_bw


V5E = HardwareSpec()
