"""PartitionSpec rules for every parameter/activation, per arch x mode.

Conventions (single pod mesh: ("data", "model"); multi-pod adds "pod"):
  * TP over "model": column-parallel in-projections, row-parallel
    out-projections, vocab-parallel embeddings, expert-parallel MoE.
  * FSDP ("zero") over "data" (+"pod" in train): weights additionally
    sharded on their non-TP dim; always on for training (optimizer state
    dominates), serve-time only for archs whose weights exceed the HBM
    replication budget (kimi-k2).
  * Serving pool: KV blocks sharded over pool_axes — ("data","model")
    when kv_heads < TP degree (DistAttention-over-model replaces
    head-TP), else ("data",) with kv heads over "model".
"""
from __future__ import annotations

import re
from typing import Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# (regex on param path, spec builder) — first match wins.
# ``f`` is the fsdp axis (or None), "model" is the TP axis.


def _rules(cfg: ModelConfig, f):
    m = "model"
    # kv projections: column-parallel ONLY when whole kv heads divide the
    # TP degree (16 on the production mesh). Otherwise the split lands
    # INSIDE head_dim and every attention use pays a gather to reassemble
    # heads (measured 259 GB/step/device on qwen3 prefill — §Perf-2);
    # replicating the small wk/wv is strictly cheaper.
    kv_tp = m if cfg.num_kv_heads % 16 == 0 else None
    q_tp = m if cfg.num_heads % 16 == 0 else None
    return [
        # --- embeddings ---
        (r"embed$",               P(m, f)),
        (r"unembed$",             P(f, m)),
        # --- attention ---
        (r"attn/wq$",             P(f, q_tp)),
        (r"attn/wk$",             P(f, kv_tp)),
        (r"attn/wv$",             P(f, kv_tp)),
        (r"attn/wo$",             P(q_tp, f)),
        (r"attn/(q|k)_norm$",     P()),
        # --- dense FFN ---
        (r"ffn/w[ig]$",           P(f, m)),
        (r"ffn/wo$",              P(m, f)),
        # --- MoE: experts over model (EP), internals over fsdp ---
        (r"moe/router$",          P(f, None)),
        (r"moe/experts/w[ig]$",   P(m, f, None)),
        (r"moe/experts/wo$",      P(m, None, f)),
        (r"moe/shared/w[ig]$",    P(None, f, m)),
        (r"moe/shared/wo$",       P(None, m, f)),
        # --- RG-LRU (recurrent width over model) ---
        (r"rglru/w_gate$",        P(f, m)),
        (r"rglru/w_rec_in$",      P(f, m)),
        (r"rglru/conv_w$",        P(None, m)),
        (r"rglru/w_[ri]$",        P(m, None)),
        (r"rglru/b_[ri]$",        P()),
        (r"rglru/log_sig_lambda$", P()),
        (r"rglru/w_out$",         P(m, f)),
        # --- xLSTM ---
        (r"blk/w_up$",            P(f, m)),
        (r"blk/w_gate$",          P(f, m)),
        (r"blk/w[qkv]$",          P(m, None)),
        (r"blk/w_if$",            P(m, None)),
        (r"blk/b_if$",            P()),
        (r"blk/gn_scale$",        P()),
        (r"blk/w_down$",          P(m, f)),
        (r"blk/w_x$",             P(f, m)),
        (r"blk/w_h$",             P(f, m)),
        (r"blk/b$",               P()),
        (r"blk/w_ff_i$",          P(f, m)),
        (r"blk/w_ff_o$",          P(m, f)),
        # --- norms / everything 1-D ---
        (r".*",                   P()),
    ]


def _spec_for_path(path: str, ndim: int, rules) -> P:
    for pat, spec in rules:
        if re.search(pat, path):
            # Stacked layer dims (scan) prepend axes: pad spec with None.
            pad = ndim - len(spec)
            if pad < 0:
                # Param is lower-rank than the rule (e.g. smoke configs
                # or tied weights): drop trailing axes.
                return P(*tuple(spec)[:ndim])
            return P(*(([None] * pad) + list(spec)))
    return P()


def param_specs(cfg: ModelConfig, params_shape, *, fsdp: bool,
                fsdp_axis="data"):
    """Pytree of PartitionSpec matching ``params_shape`` (eval_shape tree).

    Scan-stacked leading dims are left unsharded; specs are validated for
    divisibility (a dim that doesn't divide the mesh axis falls back to
    replicated on that dim).
    """
    f = fsdp_axis if fsdp else None
    rules = _rules(cfg, f)

    def one(path, leaf):
        pstr = "/".join(str(getattr(k, 'key', getattr(k, 'idx', k)))
                        for k in path)
        return _spec_for_path(pstr, np.ndim(leaf) and leaf.ndim, rules)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def validate_divisibility(specs, shapes, mesh) -> None:
    """Replace any spec axis that does not divide the dim by None."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, leaf):
        dims = leaf.shape
        out = []
        for i, ax in enumerate(tuple(spec) + (None,) * (len(dims)
                                                        - len(spec))):
            if ax is None:
                out.append(None)
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([sizes[a] for a in axs]))
            out.append(ax if dims[i] % total == 0 else None)
        return P(*out)

    return jax.tree.map(fix, specs, shapes)


# --------------------------------------------------------------------- #
# Serving-layout decisions
# --------------------------------------------------------------------- #
def serve_pool_axes(cfg: ModelConfig, mesh) -> Tuple[str, ...]:
    """Where KV pool shards live. kv_heads % tp == 0 -> heads over model
    and pool over data only; otherwise DistAttention over BOTH axes."""
    tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    axes = [a for a in mesh.axis_names if a in ("pod", "data")]
    if cfg.num_kv_heads % tp == 0:
        return tuple(axes)                     # tp_head mode
    return tuple(axes) + ("model",)            # seq_model mode


def serve_fsdp(cfg: ModelConfig, mesh) -> bool:
    """Shard weights over data at serve time only when replication would
    not fit: params_bytes / tp_degree > ~60% of chip HBM."""
    tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    per_chip = cfg.param_count() * 2 / tp
    from repro.distributed.hardware import V5E
    return per_chip > 0.6 * V5E.hbm_bytes


def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
