"""Attention layer: QKV projection, qk-norm, RoPE, backend-pluggable core.

The attention *core* (score/softmax/value) is injected so the same layer
definition serves training (causal flash), prefill (flash + KV export) and
decode (paged DistAttention with collective merge).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_rope, dense_init, rms_norm_headwise


def init_attention(key, cfg: ModelConfig):
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dtype),
        "wk": dense_init(ks[1], d, K * hd, dtype),
        "wv": dense_init(ks[2], d, K * hd, dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def qkv_project(params, x: jax.Array, positions: jax.Array, cfg: ModelConfig
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: [B, T, d] -> q [B,T,H,hd], k/v [B,T,K,hd] with qk-norm + RoPE."""
    B, T, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, T, H, hd)
    k = (x @ params["wk"]).reshape(B, T, K, hd)
    v = (x @ params["wv"]).reshape(B, T, K, hd)
    if cfg.qk_norm:
        q = rms_norm_headwise(q, params["q_norm"])
        k = rms_norm_headwise(k, params["k_norm"])
    if cfg.positional == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# Attention core signature: (q[B,T,H,hd], k[B,S,K,hd], v[B,S,K,hd]) -> [B,T,H,hd]
AttnCore = Callable[[jax.Array, jax.Array, jax.Array], jax.Array]


def apply_attention_train(
    params, x: jax.Array, positions: jax.Array, cfg: ModelConfig,
    core: AttnCore, *, window: int = 0,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence causal self-attention. Returns (out [B,T,d], (k, v))."""
    q, k, v = qkv_project(params, x, positions, cfg)
    out = core(q, k, v)
    B, T = x.shape[:2]
    out = out.reshape(B, T, -1).astype(x.dtype) @ params["wo"]
    return out, (k, v)


def make_causal_core(cfg: ModelConfig, *, backend: str = "xla",
                     window: int = 0, chunk: int = 512,
                     interpret: bool = True,
                     acc_constraint=None) -> AttnCore:
    """Build the training/prefill attention core.

    backend "xla": chunked online-softmax in pure jnp (memory-bounded,
    scan over KV chunks — the lowering used for dry-runs).
    backend "pallas": the flash-prefill kernel (interpret=True on CPU).
    backend "ref": naive full-matrix reference (tests/tiny shapes only).

    ``acc_constraint``: optional fn((o, m, l)) -> (o, m, l) applied to the
    online-softmax carry each chunk step. Without it GSPMD may reshard
    the accumulator every iteration of the KV-chunk scan — measured as 2
    full-activation all-reduces PER CHUNK per layer on small-d models
    (EXPERIMENTS.md §Perf-2).
    """
    scale = cfg.head_dim ** -0.5

    if backend == "pallas":
        from repro.kernels.ops import flash_prefill
        def core(q, k, v):
            return flash_prefill(q, k, v, scale=scale, window=window,
                                 interpret=interpret)
        return core

    if backend == "ref":
        from repro.core.attention import full_attention_prefill
        def core(q, k, v):
            return full_attention_prefill(q, k, v, scale=scale, window=window)
        return core

    from repro.core.online_softmax import (combine, empty_partial, finalize,
                                           micro_attention_prefill)

    def core(q, k, v):
        B, T, H, hd = q.shape
        S = k.shape[1]
        n_chunks = max(1, (S + chunk - 1) // chunk)
        pad = n_chunks * chunk - S
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kc = k.reshape(B, n_chunks, chunk, *k.shape[2:])
        vc = v.reshape(B, n_chunks, chunk, *v.shape[2:])
        q_pos = jnp.arange(T, dtype=jnp.int32)[None].repeat(B, 0)

        def body(acc, xs):
            kci, vci, idx = xs
            kv_pos = (idx * chunk + jnp.arange(chunk, dtype=jnp.int32)
                      )[None].repeat(B, 0)
            valid = kv_pos < S
            part = micro_attention_prefill(q, kci, vci, q_pos, kv_pos,
                                           valid, scale=scale, window=window)
            acc = combine(acc, part)
            if acc_constraint is not None:
                acc = acc_constraint(acc)
            return acc, None

        acc0 = empty_partial((B, T, H, hd), (B, T, H))
        xs = (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
              jnp.arange(n_chunks, dtype=jnp.int32))
        acc, _ = jax.lax.scan(body, acc0, xs)
        return finalize(acc[0], acc[2]).astype(q.dtype)

    return core
