"""Shared model building blocks: norms, RoPE, activations, embeddings.

Parameters are plain pytrees (nested dicts of jnp arrays); every block is a
pair of functions ``init_*(key, cfg) -> params`` and a pure ``apply``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------- #
def init_norm(cfg: ModelConfig, dim: int):
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.ones((dim,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((dim,), jnp.float32),
                "bias": jnp.zeros((dim,), jnp.float32)}
    if cfg.norm_type == "nonparametric_ln":
        return {}
    raise ValueError(cfg.norm_type)


def apply_norm(params, x, cfg: ModelConfig, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        xf = xf * params["scale"]
    else:  # layernorm / nonparametric_ln
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
        if cfg.norm_type == "layernorm":
            xf = xf * params["scale"] + params["bias"]
    return xf.astype(x.dtype)


def rms_norm_headwise(x, scale, eps: float = 1e-6):
    """Per-head RMSNorm over the last (head_dim) axis — Qwen3/Chameleon qk-norm."""
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype)


# --------------------------------------------------------------------- #
# Positional encodings
# --------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float):
    """x: [..., T, n_heads, head_dim]; positions: [..., T] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                            # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., T, hd/2]
    cos = jnp.cos(ang)[..., None, :]                         # [..., T, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions: jax.Array, dim: int):
    """[..., T] -> [..., T, dim] classic transformer sinusoids."""
    half = dim // 2
    freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                   / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------- #
# Linear / embedding initializers
# --------------------------------------------------------------------- #
def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    std = in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02
            ).astype(dtype)


# --------------------------------------------------------------------- #
# FFN (dense)
# --------------------------------------------------------------------- #
def init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, dff = cfg.d_model, d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {"wi": dense_init(k1, d, dff, dtype),
                "wg": dense_init(k2, d, dff, dtype),
                "wo": dense_init(k3, dff, d, dtype)}
    return {"wi": dense_init(k1, d, dff, dtype),
            "wo": dense_init(k3, dff, d, dtype)}


def apply_ffn(params, x, cfg: ModelConfig):
    h = x @ params["wi"]
    if cfg.activation == "swiglu":
        h = jax.nn.silu(h) * (x @ params["wg"])
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(h) * (x @ params["wg"])
    else:
        h = jax.nn.gelu(h)
    return h @ params["wo"]
