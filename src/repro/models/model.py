"""Model builder: init / full-sequence forward / single-token decode.

One entry point for all 10 assigned architectures. Layer stacks are
``jax.lax.scan`` over stacked parameter pytrees so the HLO stays compact at
512-way SPMD. Families:

  dense   — [ln1, attn, ln2, ffn] x L                  (scan)
  moe     — first_k_dense dense layers + [attn, moe] x L'  (scan)
  hybrid  — repeating block_pattern groups (+ leftover)    (scan of groups)
  ssm     — (slstm_every-1 mLSTM + 1 sLSTM) groups         (scan of groups)

The *global-view* forward here is what training and GSPMD lowering use;
the manual-collective serving step (Megatron TP + paged DistAttention)
lives in ``repro.serving.sharded_step`` and reuses the same blocks with a
TP-local config.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import sliding_window_mask_decode
from repro.core.attention import full_attention_decode
from repro.models.attention import (apply_attention_train, init_attention,
                                    make_causal_core, qkv_project)
from repro.models.common import (apply_ffn, apply_norm, embed_init,
                                 init_ffn, init_norm, dense_init,
                                 sinusoidal_embedding)
from repro.models.moe import apply_moe, init_moe, moe_aux_loss
from repro.models.rglru import (apply_rglru_block, init_rglru_block,
                                rglru_state_shape)
from repro.models.xlstm import (MLstmState, SLstmState, apply_mlstm_block,
                                apply_slstm_block, init_mlstm_block,
                                init_slstm_block, mlstm_state_init,
                                slstm_state_init)


# ===================================================================== #
# Init
# ===================================================================== #
def _init_attn_layer(key, cfg: ModelConfig, d_ff: Optional[int] = None,
                     moe: bool = False):
    ks = jax.random.split(key, 4)
    p = {"ln1": init_norm(cfg, cfg.d_model),
         "attn": init_attention(ks[0], cfg),
         "ln2": init_norm(cfg, cfg.d_model)}
    if moe:
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["ffn"] = init_ffn(ks[1], cfg, d_ff)
    return p


def _init_rglru_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {"ln1": init_norm(cfg, cfg.d_model),
            "rglru": init_rglru_block(ks[0], cfg),
            "ln2": init_norm(cfg, cfg.d_model),
            "ffn": init_ffn(ks[1], cfg)}


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    dtype = jnp.dtype(cfg.dtype)
    p: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size, dtype)

    if cfg.family == "dense":
        p["layers"] = jax.vmap(lambda k: _init_attn_layer(k, cfg))(
            jax.random.split(ks[2], cfg.num_layers))
    elif cfg.family == "moe":
        nd = cfg.first_k_dense
        if nd:
            p["dense_layers"] = jax.vmap(
                lambda k: _init_attn_layer(k, cfg, d_ff=cfg.d_ff))(
                jax.random.split(ks[3], nd))
        p["moe_layers"] = jax.vmap(
            lambda k: _init_attn_layer(k, cfg, moe=True))(
            jax.random.split(ks[2], cfg.num_layers - nd))
    elif cfg.family == "hybrid":
        pat = cfg.block_pattern
        n_groups = cfg.num_layers // len(pat)
        leftover = cfg.num_layers - n_groups * len(pat)

        def init_group(k):
            kk = jax.random.split(k, len(pat))
            g = {}
            for j, kind in enumerate(pat):
                g[f"{j}_{kind}"] = (_init_rglru_layer(kk[j], cfg)
                                    if kind == "rglru"
                                    else _init_attn_layer(kk[j], cfg))
            return g

        p["groups"] = jax.vmap(init_group)(jax.random.split(ks[2], n_groups))
        if leftover:
            def init_left(k, kinds=tuple(pat[:leftover])):
                kk = jax.random.split(k, len(kinds))
                return {f"{j}_{kind}": (_init_rglru_layer(kk[j], cfg)
                                        if kind == "rglru"
                                        else _init_attn_layer(kk[j], cfg))
                        for j, kind in enumerate(kinds)}
            p["leftover"] = init_left(ks[4])
    elif cfg.family == "ssm":
        se = cfg.slstm_every
        n_groups = cfg.num_layers // se

        def init_group(k):
            kk = jax.random.split(k, 2)
            return {
                "mlstm": jax.vmap(lambda kx: {
                    "ln": init_norm(cfg, cfg.d_model),
                    "blk": init_mlstm_block(kx, cfg)})(
                    jax.random.split(kk[0], se - 1)),
                "slstm": {"ln": init_norm(cfg, cfg.d_model),
                          "blk": init_slstm_block(kk[1], cfg)},
            }
        p["groups"] = jax.vmap(init_group)(jax.random.split(ks[2], n_groups))
    else:
        raise ValueError(cfg.family)
    return p


# ===================================================================== #
# Full-sequence forward (train / prefill lowering path)
# ===================================================================== #
def _attn_layer_fwd(lp, x, positions, cfg, core, *, moe=False,
                    capacity_factor=1.25, ep_groups=0):
    h = apply_norm(lp["ln1"], x, cfg)
    attn_out, kv = apply_attention_train(lp["attn"], h, positions, cfg, core)
    x = x + attn_out
    h = apply_norm(lp["ln2"], x, cfg)
    if moe:
        x = x + apply_moe(lp["moe"], h, cfg, capacity_factor,
                          ep_groups=ep_groups)
        aux = moe_aux_loss(lp["moe"], h, cfg)
    else:
        x = x + apply_ffn(lp["ffn"], h, cfg)
        aux = jnp.zeros((), jnp.float32)
    return x, kv, aux


def _rglru_layer_fwd(lp, x, cfg, state=None):
    h = apply_norm(lp["ln1"], x, cfg)
    mix, new_state = apply_rglru_block(lp["rglru"], h, cfg, state)
    x = x + mix
    h = apply_norm(lp["ln2"], x, cfg)
    return x + apply_ffn(lp["ffn"], h, cfg), new_state


def embed_tokens(params, cfg: ModelConfig, tokens=None, embeds=None,
                 positions=None):
    x = params["embed"][tokens] if embeds is None else embeds
    if cfg.positional == "sinusoidal":
        if positions is None:
            positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None]
        x = x + sinusoidal_embedding(positions, cfg.d_model).astype(x.dtype)
    return x


def unembed(params, cfg: ModelConfig, x):
    x = apply_norm(params["final_norm"], x, cfg)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return x @ w


def forward(params, cfg: ModelConfig, tokens=None, embeds=None, *,
            backend: str = "xla", chunk: int = 512,
            capacity_factor: float = 1.25, interpret: bool = True,
            remat: bool = False, ep_groups: int = 0,
            layer_constraints=None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence causal forward. Returns (logits [B,T,V], moe_aux).

    ``remat=True`` checkpoints each scanned layer (matmul outputs with no
    batch dims stay resident; everything else recomputes in backward) —
    the standard memory/compute trade for the train_4k cells.

    ``layer_constraints``: optional {stack_name: fn(lp)->lp} applied to
    each per-layer parameter slice INSIDE the scan body. This re-pins the
    slice to its FSDP sharding so GSPMD gathers weights one layer at a
    time instead of hoisting a full-stack all-gather out of the loop
    (which would need TBs of HBM at kimi-k2 scale).
    """
    B, T = (tokens.shape if embeds is None else embeds.shape[:2])
    positions = jnp.arange(T, dtype=jnp.int32)[None].repeat(B, 0)
    x = embed_tokens(params, cfg, tokens, embeds, positions)
    core = make_causal_core(cfg, backend=backend, chunk=chunk,
                            interpret=interpret)
    aux = jnp.zeros((), jnp.float32)
    lc = layer_constraints or {}
    def pin(name, lp):
        return lc[name](lp) if name in lc else lp

    def ckpt(fn):
        if not remat:
            return fn
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)

    if cfg.family == "dense":
        @ckpt
        def body(x, lp):
            lp = pin("layers", lp)
            x, _, _ = _attn_layer_fwd(lp, x, positions, cfg, core)
            return x, None
        x, _ = jax.lax.scan(body, x, params["layers"])

    elif cfg.family == "moe":
        if cfg.first_k_dense:
            @ckpt
            def dbody(x, lp):
                lp = pin("dense_layers", lp)
                x, _, _ = _attn_layer_fwd(lp, x, positions, cfg, core)
                return x, None
            x, _ = jax.lax.scan(dbody, x, params["dense_layers"])

        @ckpt
        def mbody(carry, lp):
            lp = pin("moe_layers", lp)
            x, aux = carry
            x, _, a = _attn_layer_fwd(lp, x, positions, cfg, core, moe=True,
                                      capacity_factor=capacity_factor,
                                      ep_groups=ep_groups)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(mbody, (x, aux), params["moe_layers"])

    elif cfg.family == "hybrid":
        wcore = make_causal_core(cfg, backend=backend, chunk=chunk,
                                 window=cfg.local_window, interpret=interpret)
        pat = cfg.block_pattern

        @ckpt
        def gbody(x, gp):
            gp = pin("groups", gp)
            for j, kind in enumerate(pat):
                lp = gp[f"{j}_{kind}"]
                if kind == "rglru":
                    x, _ = _rglru_layer_fwd(lp, x, cfg)
                else:
                    x, _, _ = _attn_layer_fwd(lp, x, positions, cfg, wcore)
            return x, None
        x, _ = jax.lax.scan(gbody, x, params["groups"])
        if "leftover" in params:
            n_left = cfg.num_layers - (cfg.num_layers // len(pat)) * len(pat)
            for j, kind in enumerate(pat[:n_left]):
                lp = params["leftover"][f"{j}_{kind}"]
                if kind == "rglru":
                    x, _ = _rglru_layer_fwd(lp, x, cfg)
                else:
                    x, _, _ = _attn_layer_fwd(lp, x, positions, cfg, wcore)

    elif cfg.family == "ssm":
        @ckpt
        def gbody(x, gp):
            gp = pin("groups", gp)
            def mbody(x, mlp):
                h = apply_norm(mlp["ln"], x, cfg)
                y, _ = apply_mlstm_block(mlp["blk"], h, cfg)
                return x + y, None
            x, _ = jax.lax.scan(mbody, x, gp["mlstm"])
            h = apply_norm(gp["slstm"]["ln"], x, cfg)
            y, _ = apply_slstm_block(gp["slstm"]["blk"], h, cfg)
            return x + y, None
        x, _ = jax.lax.scan(gbody, x, params["groups"])
    else:
        raise ValueError(cfg.family)

    return unembed(params, cfg, x), aux


# ===================================================================== #
# Single-device decode (dense in-memory cache; tests + Python engine)
# ===================================================================== #
class DecodeState(NamedTuple):
    """Simple (non-paged) cache: full KV tensors + recurrent states."""
    kv_k: Any          # dict name -> [L, B, maxlen, K, hd] or None
    kv_v: Any
    lens: jax.Array    # [B] current sequence length
    rec: Any           # family-specific recurrent states (pytree) or None


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      prefix_lens=None) -> DecodeState:
    dtype = jnp.dtype(cfg.dtype)
    K, hd = cfg.num_kv_heads, cfg.head_dim
    lens = (jnp.zeros((batch,), jnp.int32) if prefix_lens is None
            else prefix_lens)
    kv_k = kv_v = rec = None
    if cfg.family in ("dense", "moe"):
        L = cfg.num_layers
        kv_k = jnp.zeros((L, batch, max_len, K, hd), dtype)
        kv_v = jnp.zeros((L, batch, max_len, K, hd), dtype)
    elif cfg.family == "hybrid":
        n_attn = sum(1 for i in range(cfg.num_layers)
                     if cfg.layer_kind(i) == "attn")
        w = min(max_len, cfg.local_window)
        kv_k = jnp.zeros((n_attn, batch, w, K, hd), dtype)
        kv_v = jnp.zeros((n_attn, batch, w, K, hd), dtype)
        n_rg = cfg.num_layers - n_attn
        cshape, hshape = rglru_state_shape(cfg, batch)
        rec = (jnp.zeros((n_rg,) + cshape, dtype),
               jnp.zeros((n_rg,) + hshape, jnp.float32))
    elif cfg.family == "ssm":
        se = cfg.slstm_every
        ng = cfg.num_layers // se
        m0 = mlstm_state_init(cfg, batch)
        rec = {
            "mlstm": MLstmState(*[jnp.zeros((ng, se - 1) + a.shape, a.dtype)
                                  + a for a in m0]),
            "slstm": SLstmState(*[jnp.zeros((ng,) + a.shape, a.dtype) + a
                                  for a in slstm_state_init(cfg, batch)]),
        }
    return DecodeState(kv_k, kv_v, lens, rec)


def _cached_attn_decode(lp, x, state_k, state_v, lens, cfg, *, window=0):
    """x: [B, 1, d]; returns (out [B,1,d], k_new, v_new)."""
    B = x.shape[0]
    q, k, v = qkv_project(lp, x, lens[:, None], cfg)
    ql = q[:, 0]                                        # [B, H, hd]
    maxlen = state_k.shape[1]
    if window:
        pos = lens % maxlen                             # ring buffer
        k_cache = state_k.at[jnp.arange(B), pos].set(k[:, 0])
        v_cache = state_v.at[jnp.arange(B), pos].set(v[:, 0])
        kv_pos_rel = jnp.arange(maxlen, dtype=jnp.int32)[None].repeat(B, 0)
        # Absolute position of each ring slot given current write head.
        abs_pos = lens[:, None] - ((pos[:, None] - kv_pos_rel) % maxlen)
        mask = (abs_pos >= 0) & sliding_window_mask_decode(
            abs_pos, lens, window)
    else:
        k_cache = state_k.at[jnp.arange(B), lens].set(k[:, 0])
        v_cache = state_v.at[jnp.arange(B), lens].set(v[:, 0])
        mask = (jnp.arange(maxlen, dtype=jnp.int32)[None]
                <= lens[:, None])
    out = full_attention_decode(ql, k_cache, v_cache, mask)
    out = out.reshape(B, 1, -1).astype(x.dtype) @ lp["wo"]
    return out, k_cache, v_cache


def _attn_layer_decode(lp, x, ck, cv, lens, cfg, *, moe=False, window=0):
    h = apply_norm(lp["ln1"], x, cfg)
    out, ck, cv = _cached_attn_decode(lp["attn"], h, ck, cv, lens, cfg,
                                      window=window)
    x = x + out
    h = apply_norm(lp["ln2"], x, cfg)
    if moe:
        x = x + apply_moe(lp["moe"], h, cfg, capacity_factor=-1.0)
    else:
        x = x + apply_ffn(lp["ffn"], h, cfg)
    return x, ck, cv


def decode_step(params, cfg: ModelConfig, state: DecodeState,
                tokens: jax.Array) -> Tuple[jax.Array, DecodeState]:
    """One decode step for a batch. tokens: [B] -> (logits [B,V], state)."""
    x = embed_tokens(params, cfg, tokens[:, None], None,
                     positions=state.lens[:, None])
    lens = state.lens

    if cfg.family in ("dense", "moe"):
        ck_all, cv_all = state.kv_k, state.kv_v
        if cfg.family == "dense":
            def body(x, xs):
                lp, ck, cv = xs
                x, ck, cv = _attn_layer_decode(lp, x, ck, cv, lens, cfg)
                return x, (ck, cv)
            x, (ck_all, cv_all) = jax.lax.scan(
                body, x, (params["layers"], ck_all, cv_all))
        else:
            nd = cfg.first_k_dense
            if nd:
                def dbody(x, xs):
                    lp, ck, cv = xs
                    x, ck, cv = _attn_layer_decode(lp, x, ck, cv, lens, cfg)
                    return x, (ck, cv)
                x, (ck_d, cv_d) = jax.lax.scan(
                    dbody, x, (params["dense_layers"],
                               ck_all[:nd], cv_all[:nd]))

            def mbody(x, xs):
                lp, ck, cv = xs
                x, ck, cv = _attn_layer_decode(lp, x, ck, cv, lens, cfg,
                                               moe=True)
                return x, (ck, cv)
            x, (ck_m, cv_m) = jax.lax.scan(
                mbody, x, (params["moe_layers"], ck_all[nd:], cv_all[nd:]))
            ck_all = jnp.concatenate([ck_d, ck_m], 0) if nd else ck_m
            cv_all = jnp.concatenate([cv_d, cv_m], 0) if nd else cv_m
        new_state = DecodeState(ck_all, cv_all, lens + 1, None)

    elif cfg.family == "hybrid":
        conv_c, lru_h = state.rec
        ck_all, cv_all = state.kv_k, state.kv_v
        ai = ri = 0
        new_ck, new_cv, new_cc, new_h = [], [], [], []
        for i in range(cfg.num_layers):
            kind = cfg.layer_kind(i)
            lp = _layer_params(params, cfg, i)
            if kind == "attn":
                x, ck, cv = _attn_layer_decode(
                    lp, x, ck_all[ai], cv_all[ai], lens, cfg,
                    window=cfg.local_window)
                new_ck.append(ck); new_cv.append(cv)
                ai += 1
            else:
                h = apply_norm(lp["ln1"], x, cfg)
                mix, (cc, hh) = apply_rglru_block(
                    lp["rglru"], h, cfg, (conv_c[ri], lru_h[ri]),
                    decode=True)
                x = x + mix
                h2 = apply_norm(lp["ln2"], x, cfg)
                x = x + apply_ffn(lp["ffn"], h2, cfg)
                new_cc.append(cc); new_h.append(hh)
                ri += 1
        new_state = DecodeState(jnp.stack(new_ck), jnp.stack(new_cv),
                                lens + 1,
                                (jnp.stack(new_cc), jnp.stack(new_h)))

    elif cfg.family == "ssm":
        rec = state.rec

        def gbody(x, xs):
            gp, mst, sst = xs

            def mbody(x, ms):
                mlp, st = ms
                h = apply_norm(mlp["ln"], x, cfg)
                y, st = apply_mlstm_block(mlp["blk"], h, cfg,
                                          MLstmState(*st), decode=True)
                return x + y, tuple(st)
            x, mst = jax.lax.scan(mbody, x, (gp["mlstm"], tuple(mst)))
            h = apply_norm(gp["slstm"]["ln"], x, cfg)
            y, sst = apply_slstm_block(gp["slstm"]["blk"], h, cfg,
                                       SLstmState(*sst), decode=True)
            return x + y, (mst, tuple(sst))

        x, (mst, sst) = jax.lax.scan(
            gbody, x, (params["groups"], tuple(rec["mlstm"]),
                       tuple(rec["slstm"])))
        new_state = DecodeState(None, None, lens + 1,
                                {"mlstm": MLstmState(*mst),
                                 "slstm": SLstmState(*sst)})
    else:
        raise ValueError(cfg.family)

    logits = unembed(params, cfg, x[:, 0])
    return logits, new_state


def _layer_params(params, cfg: ModelConfig, i: int):
    """Extract layer-i params from the stacked pytrees (hybrid family)."""
    pat = cfg.block_pattern
    ng = cfg.num_layers // len(pat)
    g, j = divmod(i, len(pat))
    kind = pat[j]
    if g < ng:
        return jax.tree.map(lambda a: a[g], params["groups"][f"{j}_{kind}"])
    return params["leftover"][f"{j}_{kind}"]
