"""Griffin/RecurrentGemma recurrent block: Conv1D(4) + RG-LRU, gated.

Block: x -> { gate branch: linear -> GeLU } * { recurrent branch:
linear -> causal Conv1D(width 4) -> RG-LRU } -> linear out.

RG-LRU (real-gated linear recurrent unit):
    r_t = sigmoid(W_r x_t + b_r)          recurrence gate
    i_t = sigmoid(W_i x_t + b_i)          input gate
    a_t = exp(c * r_t * log_sigmoid(L))   L learnable, c = -8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses ``jax.lax.associative_scan`` over the sequence (TPU-friendly
log-depth); decode is the O(1)-state recurrent step — the reason
DistAttention has nothing to pool for these layers (DESIGN.md).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init

_C = 8.0
_CONV_W = 4


def init_rglru_block(key, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_width or d
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    # Lambda init so a = sigmoid(L)^(c*r) sits in [0.9, 0.999] (Griffin).
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    log_sig_l = jnp.log(u ** (1.0 / _C))  # log(sigmoid(L)) implicitly
    return {
        "w_gate": dense_init(ks[0], d, w, dtype),
        "w_rec_in": dense_init(ks[1], d, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (_CONV_W, w), jnp.float32)
                   * 0.1).astype(dtype),
        "w_r": dense_init(ks[3], w, w, dtype),
        "w_i": dense_init(ks[4], w, w, dtype),
        "b_r": jnp.zeros((w,), jnp.float32),
        "b_i": jnp.zeros((w,), jnp.float32),
        "log_sig_lambda": log_sig_l,                 # [w] f32
        "w_out": dense_init(jax.random.fold_in(key, 7), w, d, dtype),
    }


def _gates(p, x):
    """x: [..., w] (conv output) -> (log_a [..., w] f32, gated_in)."""
    r = jax.nn.sigmoid((x @ p["w_r"]).astype(jnp.float32) + p["b_r"])
    i = jax.nn.sigmoid((x @ p["w_i"]).astype(jnp.float32) + p["b_i"])
    log_a = _C * r * p["log_sig_lambda"]             # <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i \
        * x.astype(jnp.float32)
    return log_a, gated


def rglru_scan(p, x: jax.Array, h0: jax.Array | None = None):
    """Parallel RG-LRU over [B, T, w] via associative scan. Returns (y, h_T)."""
    B, T, w = x.shape
    log_a, gated = _gates(p, x)                      # [B, T, w] f32
    if h0 is not None:
        # Fold the carry in as a virtual step 0 with a=1 contribution.
        log_a = jnp.concatenate([jnp.zeros((B, 1, w)), log_a], 1)
        gated = jnp.concatenate([h0.astype(jnp.float32)[:, None], gated], 1)

    def op(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, b1 * jnp.exp(a2) + b2

    la, h = jax.lax.associative_scan(op, (log_a, gated), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p, x: jax.Array, h: jax.Array):
    """Single decode step. x: [B, w] conv output, h: [B, w] f32 state."""
    log_a, gated = _gates(p, x[:, None])
    h_new = jnp.exp(log_a[:, 0]) * h + gated[:, 0]
    return h_new.astype(x.dtype), h_new


def causal_conv1d(p, x: jax.Array, carry: jax.Array | None = None):
    """Depthwise causal conv width 4 over [B, T, w]; carry [B, 3, w]."""
    B, T, w = x.shape
    if carry is None:
        carry = jnp.zeros((B, _CONV_W - 1, w), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)         # [B, T+3, w]
    out = jnp.zeros((B, T, w), jnp.float32)
    for i in range(_CONV_W):
        out = out + xp[:, i:i + T].astype(jnp.float32) \
            * p["conv_w"][i].astype(jnp.float32)
    return out.astype(x.dtype), xp[:, -( _CONV_W - 1):]


def apply_rglru_block(p, x: jax.Array, cfg: ModelConfig,
                      state: Tuple[jax.Array, jax.Array] | None = None,
                      *, decode: bool = False):
    """Full Griffin recurrent block. x: [B, T, d].

    state = (conv_carry [B,3,w], lru_h [B,w]); returns (y, new_state).
    """
    gate = jax.nn.gelu(x @ p["w_gate"])              # [B, T, w]
    rec = x @ p["w_rec_in"]
    if decode:
        conv_carry, h = state
        rec_c, conv_carry = causal_conv1d(p, rec, conv_carry)
        y_rec, h = rglru_step(p, rec_c[:, 0], h)
        y_rec = y_rec[:, None]
    else:
        if state is None:
            conv_carry, h0 = None, None
        else:
            conv_carry, h0 = state
        rec_c, conv_carry = causal_conv1d(p, rec, conv_carry)
        y_rec, h = rglru_scan(p, rec_c, h0)
    y = (gate * y_rec) @ p["w_out"]
    return y, (conv_carry, h)


def rglru_state_shape(cfg: ModelConfig, batch: int):
    w = cfg.lru_width or cfg.d_model
    return ((batch, _CONV_W - 1, w), (batch, w))
