"""Mixture-of-Experts FFN: shared + routed experts, top-k, EP-shardable.

Dispatch is sort-based with per-expert capacity (the TPU-friendly
formulation): (token, k) assignments are sorted by expert id, each expert
receives a fixed-capacity [E, C, d] buffer (scatter-add), expert FFNs run
as one grouped einsum over the expert axis (shardable over the ``model``
mesh axis = expert parallelism), and outputs gather back with the gate
weights. Overflow beyond capacity drops tokens (standard); tests use a
no-drop capacity. FLOPs stay O(N * top_k * d * d_ff) — active experts
only — unlike a dense all-experts dispatch.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init


def init_moe(key, cfg: ModelConfig):
    d, dff = cfg.d_model, cfg.moe_d_ff
    E, SE = cfg.num_experts, cfg.num_shared_experts
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    glu = cfg.activation in ("swiglu", "geglu")

    def bank(key, n):
        kk = jax.random.split(key, 3)
        std_i, std_o = d ** -0.5, dff ** -0.5
        p = {"wi": (jax.random.normal(kk[0], (n, d, dff), jnp.float32)
                    * std_i).astype(dtype),
             "wo": (jax.random.normal(kk[2], (n, dff, d), jnp.float32)
                    * std_o).astype(dtype)}
        if glu:
            p["wg"] = (jax.random.normal(kk[1], (n, d, dff), jnp.float32)
                       * std_i).astype(dtype)
        return p

    p = {"router": dense_init(ks[0], d, E, dtype), "experts": bank(ks[1], E)}
    if SE:
        p["shared"] = bank(ks[2], SE)
    return p


def _expert_ffn(bank, x_e, cfg: ModelConfig, ep_pin: bool = False):
    """x_e: [E, C, d] tokens grouped per expert -> [E, C, d].

    ``ep_pin``: explicitly gather each rank's OWN experts over the fsdp
    (data) axis before the einsum. Without it, the einsum's lhs-C(data) /
    rhs-d(data) conflict makes GSPMD gather ALL experts to every device
    (measured 33.8 GB/layer vs 2.1 GB for the rank's 24 — §Perf-3).
    """
    wi, wo = bank["wi"], bank["wo"]
    wg = bank.get("wg")
    if ep_pin:
        from jax.sharding import PartitionSpec as P
        def pin(w):
            return jax.lax.with_sharding_constraint(
                w, P("model", None, None))
        wi, wo = pin(wi), pin(wo)
        wg = pin(wg) if wg is not None else None
    h = jnp.einsum("ecd,edf->ecf", x_e, wi)
    if cfg.activation == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", x_e, wg)
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(h) * jnp.einsum("ecd,edf->ecf", x_e, wg)
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def moe_capacity(n_tokens: int, cfg: ModelConfig,
                 capacity_factor: float = 1.25) -> int:
    """Per-expert buffer size C."""
    if capacity_factor <= 0:                       # no-drop mode (tests)
        return n_tokens
    c = math.ceil(n_tokens * cfg.top_k / cfg.num_experts * capacity_factor)
    return max(cfg.top_k, min(n_tokens, c))


def _dispatch_combine(xt, params, cfg: ModelConfig, C: int,
                      expert_fn) -> jax.Array:
    """Sort-based dispatch for ONE token group. xt: [N, d] -> [N, d]."""
    E, topk = cfg.num_experts, cfg.top_k
    N, d = xt.shape
    logits = (xt @ params["router"]).astype(jnp.float32)      # [N, E]
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), topk)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = idx.reshape(-1)                                  # [N*k]
    flat_t = jnp.repeat(jnp.arange(N, dtype=jnp.int32), topk)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e)                               # stable
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]

    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                      # exclusive
    pos = jnp.arange(N * topk, dtype=jnp.int32) - starts[se]
    keep = pos < C
    safe_p = jnp.where(keep, pos, 0)

    tok = xt[st] * keep[:, None].astype(xt.dtype)             # [N*k, d]
    buf = jnp.zeros((E, C, d), xt.dtype).at[se, safe_p].add(tok)
    y_buf = expert_fn(buf)                                    # [E, C, d]
    w = (sg * keep).astype(xt.dtype)
    return jnp.zeros((N, d), xt.dtype).at[st].add(
        y_buf[se, safe_p] * w[:, None])


def apply_moe(params, x: jax.Array, cfg: ModelConfig,
              capacity_factor: float = 1.25,
              ep_groups: int = 0) -> jax.Array:
    """x: [B, T, d] -> [B, T, d]. Top-k routed + always-on shared experts.

    ``ep_groups > 1`` enables the 2D expert-parallel formulation for mesh
    execution (production axes "data" x "model"): the token stream splits
    into ``ep_groups`` DATA-LOCAL groups, each sorting/scattering its own
    tokens (a single global argsort/scatter otherwise makes GSPMD
    materialize terabyte-scale gathered intermediates — EXPERIMENTS.md
    §Perf-3), and the grouped buffers are pinned to
    [E->model, group->data] for the expert einsum, so tokens never leave
    their data rank and expert weights move only as per-layer FSDP
    gathers.
    """
    from jax.sharding import PartitionSpec as P
    wsc = jax.lax.with_sharding_constraint
    B, T, d = x.shape
    E = cfg.num_experts
    xt = x.reshape(-1, d)                                     # [N, d]
    N = xt.shape[0]

    if ep_groups and ep_groups > 1 and N % ep_groups == 0:
        G = ep_groups
        Ng = N // G
        Cg = moe_capacity(Ng, cfg, capacity_factor)
        xg = wsc(xt.reshape(G, Ng, d), P("data", None, None))

        def expert_fn(buf_g):          # [G, E, Cg, d] -> same
            b = jnp.moveaxis(buf_g, 1, 0)                     # [E, G, Cg, d]
            b = wsc(b, P("model", "data", None, None))
            h = _expert_ffn(params["experts"],
                            b.reshape(E, G * Cg, d), cfg, ep_pin=True)
            h = h.reshape(E, G, Cg, d)
            h = wsc(h, P("model", "data", None, None))
            return jnp.moveaxis(h, 0, 1)                      # [G, E, Cg, d]

        # Two-phase: per-group dispatch -> joint expert compute (E over
        # "model") -> per-group combine.
        E_, topk = cfg.num_experts, cfg.top_k

        def phase1(xt_i):
            logits = (xt_i @ params["router"]).astype(jnp.float32)
            gates, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), topk)
            gates = gates / jnp.maximum(gates.sum(-1, keepdims=True),
                                        1e-9)
            flat_e = idx.reshape(-1)
            flat_t = jnp.repeat(jnp.arange(Ng, dtype=jnp.int32), topk)
            flat_g = gates.reshape(-1)
            order = jnp.argsort(flat_e)
            se, st, sg = flat_e[order], flat_t[order], flat_g[order]
            counts = jnp.zeros((E_,), jnp.int32).at[flat_e].add(1)
            starts = jnp.cumsum(counts) - counts
            pos = jnp.arange(Ng * topk, dtype=jnp.int32) - starts[se]
            keep = pos < Cg
            safe_p = jnp.where(keep, pos, 0)
            tok = xt_i[st] * keep[:, None].astype(xt_i.dtype)
            buf = jnp.zeros((E_, Cg, d), xt_i.dtype).at[se, safe_p].add(tok)
            return buf, (se, st, sg, keep, safe_p)

        buf_g, meta = jax.vmap(phase1)(xg)
        buf_g = wsc(buf_g, P("data", None, None, None))
        y_buf_g = expert_fn(buf_g)
        y_buf_g = wsc(y_buf_g, P("data", None, None, None))

        def phase2(y_buf, xt_i, m):
            se, st, sg, keep, safe_p = m
            w = (sg * keep).astype(xt_i.dtype)
            return jnp.zeros((Ng, d), xt_i.dtype).at[st].add(
                y_buf[se, safe_p] * w[:, None])

        y = jax.vmap(phase2)(y_buf_g, xg, meta).reshape(N, d)
    else:
        C = moe_capacity(N, cfg, capacity_factor)
        y = _dispatch_combine(xt, params, cfg, C,
                              lambda buf: _expert_ffn(params["experts"],
                                                      buf, cfg))

    if cfg.num_shared_experts:
        xs = jnp.broadcast_to(xt, (cfg.num_shared_experts, N, d))
        y = y + _expert_ffn(params["shared"], xs, cfg).sum(0).astype(xt.dtype)
    return y.reshape(B, T, d).astype(x.dtype)


def moe_aux_loss(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style) for training."""
    xt = x.reshape(-1, x.shape[-1])
    logits = (xt @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    _, idx = jax.lax.top_k(probs, cfg.top_k)
    hard = jax.nn.one_hot(idx, cfg.num_experts).sum(1)        # [N, E]
    return cfg.num_experts * jnp.sum(hard.mean(0) * probs.mean(0))
