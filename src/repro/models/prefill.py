"""Prefill-into-cache and the paged / distributed decode steps (dense/moe).

``prefill`` runs the full-sequence forward while capturing per-layer KV
(and recurrent states) into a ``DecodeState`` so generation can continue
token-by-token. On the dense/moe SERVING path it is no longer the
admission step: ``prefill_chunk_paged`` streams a prompt into the block
pools chunk-by-chunk — each fixed-shape step runs the causal core over
the chunk plus a paged MicroAttention partial over every already-written
pool span (local + creditors), LSE-merges them, and scatters the chunk's
KV rows straight into pre-reserved blocks. Peak admission memory is
O(chunk + pool) and compile shapes never depend on prompt length;
``prefill`` remains the hybrid/ssm admission path and the equivalence
oracle for the chunked pipeline.

``decode_step_paged`` is the serving data path: every request's KV lives
in fixed-shape block pools (``pool_k/pool_v: [L, NB, bs, K, hd]`` per
rank) and is addressed purely through block tables. One local pool is
updated in place (the new token's KV is scattered into its tail block);
any number of remote (creditor) pools are read-only. Each rank's paged
MicroAttention partial (paper Eq. 2) is LSE-merged (Eq. 3) — tables are
bucketed by the caller so the step compiles O(#buckets * #rank-counts)
times, never per sequence length.

``decode_step_dist`` is the older dense-span formulation (local ring +
concatenated remote arrays); it remains as an equivalence oracle for the
paged path and for the mesh/collective version in
``repro.serving.sharded_step``.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.online_softmax import (combine, finalize,
                                       micro_attention_decode,
                                       micro_attention_prefill)
from repro.models.attention import make_causal_core, qkv_project
from repro.models.common import apply_ffn, apply_norm
from repro.models.model import (DecodeState, _attn_layer_fwd, _rglru_layer_fwd,
                                embed_tokens, init_decode_state, unembed)
from repro.models.moe import apply_moe
from repro.models.xlstm import (MLstmState, SLstmState, apply_mlstm_block,
                                apply_slstm_block)


# ===================================================================== #
# Prefill
# ===================================================================== #
def _ring_fill(cache, k, T, maxlen):
    """Write the last min(T, maxlen) tokens of k [B,T,K,hd] into ring cache
    [B, maxlen, K, hd] at slots (abs_pos % maxlen)."""
    n = min(T, maxlen)
    p0 = T - n
    abs_pos = p0 + jnp.arange(n)
    slots = abs_pos % maxlen
    return cache.at[:, slots].set(k[:, p0:p0 + n])


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None, *,
            max_len: int, backend: str = "xla", chunk: int = 512,
            capacity_factor: float = -1.0,
            ) -> Tuple[jax.Array, DecodeState]:
    """Uniform-length prefill. Returns (logits_last [B,V], DecodeState).

    The DecodeState local cache keeps the LAST min(T, max_len) tokens
    (ring layout); the caller is responsible for placing the overflowed
    prefix [0, T-max_len) on creditor instances (``start`` bookkeeping
    lives in the serving runtime).
    """
    B, T = (tokens.shape if embeds is None else embeds.shape[:2])
    positions = jnp.arange(T, dtype=jnp.int32)[None].repeat(B, 0)
    x = embed_tokens(params, cfg, tokens, embeds, positions)
    core = make_causal_core(cfg, backend=backend, chunk=chunk)
    state = init_decode_state(cfg, B, max_len)
    lens = jnp.full((B,), T, jnp.int32)

    if cfg.family in ("dense", "moe"):
        def make_body(moe):
            def body(x, lp):
                x, kv, _ = _attn_layer_fwd(lp, x, positions, cfg, core,
                                           moe=moe,
                                           capacity_factor=capacity_factor)
                return x, kv
            return body
        if cfg.family == "dense":
            x, (ks, vs) = jax.lax.scan(make_body(False), x, params["layers"])
        else:
            nd = cfg.first_k_dense
            kds = vds = None
            if nd:
                x, (kds, vds) = jax.lax.scan(make_body(False), x,
                                             params["dense_layers"])
            x, (kms, vms) = jax.lax.scan(make_body(True), x,
                                         params["moe_layers"])
            ks = jnp.concatenate([kds, kms], 0) if nd else kms
            vs = jnp.concatenate([vds, vms], 0) if nd else vms
        # ks: [L, B, T, K, hd] -> ring-fill each layer.
        fill = jax.vmap(lambda c, k: _ring_fill(c, k, T, max_len))
        state = state._replace(kv_k=fill(state.kv_k, ks),
                               kv_v=fill(state.kv_v, vs), lens=lens)

    elif cfg.family == "hybrid":
        pat = cfg.block_pattern
        wcore = make_causal_core(cfg, backend=backend, chunk=chunk,
                                 window=cfg.local_window)
        w = state.kv_k.shape[2]

        def gbody(x, gp):
            kvs = []
            rec = []
            for j, kind in enumerate(pat):
                lp = gp[f"{j}_{kind}"]
                if kind == "rglru":
                    x, st = _rglru_layer_fwd(lp, x, cfg)
                    rec.append(st)
                else:
                    x, kv, _ = _attn_layer_fwd(lp, x, positions, cfg, wcore)
                    kvs.append(kv)
            return x, (kvs, rec)
        x, (kvs, rec) = jax.lax.scan(gbody, x, params["groups"])
        # kvs: list (per attn slot in pattern) of (k [G,B,T,K,hd], v).
        n_left = cfg.num_layers - (cfg.num_layers // len(pat)) * len(pat)
        left_rec = []
        if n_left:
            for j, kind in enumerate(pat[:n_left]):
                lp = params["leftover"][f"{j}_{kind}"]
                assert kind == "rglru"
                x, st = _rglru_layer_fwd(lp, x, cfg)
                left_rec.append(st)
        ks = jnp.concatenate([kv[0] for kv in kvs], 0)   # [n_attn,B,T,K,hd]
        vs = jnp.concatenate([kv[1] for kv in kvs], 0)
        fill = jax.vmap(lambda c, k: _ring_fill(c, k, T, w))
        # rec from the group scan: each element r = (conv [ng,B,3,w], h
        # [ng,B,w]); leftover layers contribute unstacked (B,...) states.
        convs = [r[0] for r in rec] + [r[0][None] for r in left_rec]
        hs = [r[1] for r in rec] + [r[1][None] for r in left_rec]
        conv = jnp.concatenate(convs, 0)
        h = jnp.concatenate(hs, 0)
        state = state._replace(kv_k=fill(state.kv_k, ks),
                               kv_v=fill(state.kv_v, vs),
                               lens=lens, rec=(conv, h))

    elif cfg.family == "ssm":
        def gbody(x, gp):
            def mbody(x, mlp):
                hh = apply_norm(mlp["ln"], x, cfg)
                y, st = apply_mlstm_block(mlp["blk"], hh, cfg)
                return x + y, tuple(st)
            x, mst = jax.lax.scan(mbody, x, gp["mlstm"])
            hh = apply_norm(gp["slstm"]["ln"], x, cfg)
            y, sst = apply_slstm_block(gp["slstm"]["blk"], hh, cfg)
            return x + y, (mst, tuple(sst))
        x, (mst, sst) = jax.lax.scan(gbody, x, params["groups"])
        state = state._replace(lens=lens,
                               rec={"mlstm": MLstmState(*mst),
                                    "slstm": SLstmState(*sst)})
    else:
        raise ValueError(cfg.family)

    logits = unembed(params, cfg, x[:, -1])
    return logits, state


# ===================================================================== #
# Slot management (engine batches individual prefills into fixed slots)
# ===================================================================== #
def repack_ring(state: DecodeState, new_maxlen: int,
                n_keep: Optional[int] = None) -> DecodeState:
    """Convert a full prefill cache (max_len = T, identity layout) into a
    ring cache of ``new_maxlen`` holding the tail ``n_keep`` tokens.

    Only the non-pooled serving path (hybrid/ssm engines) uses this; the
    dense/moe path writes prefill KV straight into the block pool.
    """
    T = int(state.lens[0])
    n = min(T, new_maxlen if n_keep is None else n_keep)
    k = state.kv_k[:, :, T - n:T]
    v = state.kv_v[:, :, T - n:T]
    slots = (T - n + jnp.arange(n)) % new_maxlen
    L, B = state.kv_k.shape[:2]
    shape = (L, B, new_maxlen) + state.kv_k.shape[3:]
    nk = jnp.zeros(shape, state.kv_k.dtype).at[:, :, slots].set(k)
    nv = jnp.zeros(shape, state.kv_v.dtype).at[:, :, slots].set(v)
    return DecodeState(nk, nv, state.lens, state.rec)


def batch_axis_map(cfg: ModelConfig):
    """Batch-axis index for each DecodeState field's arrays."""
    if cfg.family in ("dense", "moe"):
        return {"kv": 1, "rec": None}
    if cfg.family == "hybrid":
        return {"kv": 1, "rec": 1}
    return {"kv": None, "rec": {"mlstm": 2, "slstm": 1}}


def write_slot(state: DecodeState, slot: int, req: DecodeState,
               cfg: ModelConfig) -> DecodeState:
    """Copy a single-request (B=1) DecodeState into batch slot ``slot``."""
    ax = batch_axis_map(cfg)

    def put(dst, src, axis):
        idx = [slice(None)] * dst.ndim
        idx[axis] = slot
        src_idx = [slice(None)] * src.ndim
        src_idx[axis] = 0
        return dst.at[tuple(idx)].set(src[tuple(src_idx)])

    kv_k, kv_v, rec = state.kv_k, state.kv_v, state.rec
    if state.kv_k is not None:
        # Ring layouts may differ if max_len differs; require equal here.
        assert state.kv_k.shape[2] == req.kv_k.shape[2], \
            "slot and request cache sizes must match"
        kv_k = put(state.kv_k, req.kv_k, ax["kv"])       # [L, B, ...]
        kv_v = put(state.kv_v, req.kv_v, ax["kv"])
    if state.rec is not None:
        if cfg.family == "hybrid":
            rec = (put(state.rec[0], req.rec[0], ax["rec"]),  # [n_rg,B,3,w]
                   put(state.rec[1], req.rec[1], ax["rec"]))
        else:
            rec = {
                "mlstm": MLstmState(*[put(d, s, ax["rec"]["mlstm"])
                                      for d, s in zip(state.rec["mlstm"],
                                                      req.rec["mlstm"])]),
                "slstm": SLstmState(*[put(d, s, ax["rec"]["slstm"])
                                      for d, s in zip(state.rec["slstm"],
                                                      req.rec["slstm"])]),
            }
    lens = state.lens.at[slot].set(req.lens[0])
    return DecodeState(kv_k, kv_v, lens, rec)


# ===================================================================== #
# Distributed decode step (dense/moe): local ring span + remote spans
# ===================================================================== #
def _ring_mask(length, start, maxlen):
    """[B, maxlen] validity for ring slots holding abs pos in [start, len).

    ``length``: [B] sequence length AFTER the current token's write. Slot j
    holds absolute position p = (len-1) - ((len-1-j) mod maxlen); it is
    valid iff p >= max(start, 0).
    """
    j = jnp.arange(maxlen, dtype=jnp.int32)[None]
    last = (length - 1)[:, None]
    p = last - ((last - j) % maxlen)
    return (p >= start[:, None]) & (p >= 0)


def _dist_attn_decode(lp, x, ck, cv, lens, start, rk, rv, rlen, cfg):
    """Local ring partial + remote span partial, merged (paper Eq. 3)."""
    B = x.shape[0]
    q, k, v = qkv_project(lp, x, lens[:, None], cfg)
    ql = q[:, 0]
    maxlen = ck.shape[1]
    slot = lens % maxlen
    ck = ck.at[jnp.arange(B), slot].set(k[:, 0])
    cv = cv.at[jnp.arange(B), slot].set(v[:, 0])
    lmask = _ring_mask(lens + 1, jnp.maximum(start, 0), maxlen)
    local = micro_attention_decode(ql, ck, cv, lmask)
    rmask = (jnp.arange(rk.shape[1], dtype=jnp.int32)[None]
             < rlen[:, None])
    remote = micro_attention_decode(ql, rk, rv, rmask)
    o, m, l = combine(local, remote)
    out = finalize(o, l)
    out = out.reshape(B, 1, -1).astype(x.dtype) @ lp["wo"]
    return out, ck, cv


def decode_step_dist(params, cfg: ModelConfig, state: DecodeState,
                     tokens: jax.Array, start: jax.Array,
                     remote_k: jax.Array, remote_v: jax.Array,
                     remote_len: jax.Array
                     ) -> Tuple[jax.Array, DecodeState]:
    """DistAttention decode for dense/moe: KV = local[start, len) + remote.

    remote_k/v: [L, B, S_r, K, hd] concatenated creditor spans (token
    positions [0, start)); remote_len: [B] valid remote tokens.
    """
    assert cfg.family in ("dense", "moe"), "only attention archs pool KV"
    lens = state.lens
    x = embed_tokens(params, cfg, tokens[:, None], None,
                     positions=lens[:, None])

    def make_body(moe):
        def body(x, xs):
            lp, ck, cv, rk, rv = xs
            h = apply_norm(lp["ln1"], x, cfg)
            out, ck, cv = _dist_attn_decode(lp["attn"], h, ck, cv, lens,
                                            start, rk, rv, remote_len, cfg)
            x = x + out
            h = apply_norm(lp["ln2"], x, cfg)
            if moe:
                x = x + apply_moe(lp["moe"], h, cfg, capacity_factor=-1.0)
            else:
                x = x + apply_ffn(lp["ffn"], h, cfg)
            return x, (ck, cv)
        return body

    if cfg.family == "dense":
        x, (ck, cv) = jax.lax.scan(
            make_body(False), x,
            (params["layers"], state.kv_k, state.kv_v, remote_k, remote_v))
    else:
        nd = cfg.first_k_dense
        if nd:
            x, (ckd, cvd) = jax.lax.scan(
                make_body(False), x,
                (params["dense_layers"], state.kv_k[:nd], state.kv_v[:nd],
                 remote_k[:nd], remote_v[:nd]))
        x, (ckm, cvm) = jax.lax.scan(
            make_body(True), x,
            (params["moe_layers"], state.kv_k[nd:], state.kv_v[nd:],
             remote_k[nd:], remote_v[nd:]))
        ck = jnp.concatenate([ckd, ckm], 0) if nd else ckm
        cv = jnp.concatenate([cvd, cvm], 0) if nd else cvm

    logits = unembed(params, cfg, x[:, 0])
    return logits, DecodeState(ck, cv, lens + 1, None)


# ===================================================================== #
# Paged decode step (dense/moe): KV pool + block tables, fixed shapes
# ===================================================================== #
# Incremented once per trace of the jitted paged step; serving tests use
# it to assert the recompile count is bounded by the table buckets and
# rank counts, never by remote-span length.
_PAGED_TRACE_COUNT = 0


def paged_trace_count() -> int:
    return _PAGED_TRACE_COUNT


def _paged_partial(q, pk, pv, table, tail, backend):
    """One rank's MicroAttention partial over its pool (paper Eq. 2)."""
    if backend == "pallas":
        from repro.kernels.ops import paged_micro_attention
        return paged_micro_attention(q, pk, pv, table, tail,
                                     backend="pallas")
    from repro.kernels.ops import paged_micro_attention_jnp
    return paged_micro_attention_jnp(q, pk, pv, table, tail)


def _scan_dense_moe(params, cfg, x, pool_k, pool_v, remote_k, remote_v,
                    make_body):
    """Layer-stack scan shared by the paged decode and prefill steps.

    ``make_body(moe)`` returns a scan body consuming
    ``(x, (lp, pk, pv, rks, rvs))``; per-layer pool slices (and the
    remote tuples) are split across the dense/moe sub-stacks and the
    scan outputs re-concatenated along the layer axis.
    """
    if cfg.family == "dense":
        return jax.lax.scan(make_body(False), x,
                            (params["layers"], pool_k, pool_v,
                             remote_k, remote_v))
    nd = cfg.first_k_dense
    ys_d = None
    if nd:
        x, ys_d = jax.lax.scan(
            make_body(False), x,
            (params["dense_layers"], pool_k[:nd], pool_v[:nd],
             tuple(a[:nd] for a in remote_k),
             tuple(a[:nd] for a in remote_v)))
    x, ys_m = jax.lax.scan(
        make_body(True), x,
        (params["moe_layers"], pool_k[nd:], pool_v[nd:],
         tuple(a[nd:] for a in remote_k),
         tuple(a[nd:] for a in remote_v)))
    if nd:
        ys_m = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                            ys_d, ys_m)
    return x, ys_m


def _paged_attn_decode(lp, x, lens, pk, pv, rks, rvs, tables, tails,
                       write_block, write_off, cfg, backend):
    """Paged DistAttention for one layer: write tail token, merge ranks.

    pk/pv: [NB, bs, K, hd] — the LOCAL pool's layer slice (updated);
    rks/rvs: tuples of remote layer slices (read-only);
    tables: [P, B, MB] block tables (rank 0 = local); tails: [P, B].
    """
    B = x.shape[0]
    q, k, v = qkv_project(lp, x, lens[:, None], cfg)
    ql = q[:, 0]
    # Append this step's KV into each request's tail block. Inactive
    # slots carry an out-of-range block index; mode="drop" skips them.
    pk = pk.at[write_block, write_off].set(k[:, 0].astype(pk.dtype),
                                           mode="drop")
    pv = pv.at[write_block, write_off].set(v[:, 0].astype(pv.dtype),
                                           mode="drop")
    part = _paged_partial(ql, pk, pv, tables[0], tails[0], backend)
    for p, (rk, rv) in enumerate(zip(rks, rvs), start=1):
        part = combine(part, _paged_partial(ql, rk, rv, tables[p],
                                            tails[p], backend))
    out = finalize(part[0], part[2])
    out = out.reshape(B, 1, -1).astype(x.dtype) @ lp["wo"]
    return out, pk, pv


@functools.partial(jax.jit, static_argnames=("cfg", "backend"),
                   donate_argnames=("pool_k", "pool_v"))
def _decode_step_paged_jit(params, tokens, lens, pool_k, pool_v,
                           remote_k, remote_v, tables, tails,
                           write_block, write_off, *, cfg, backend):
    global _PAGED_TRACE_COUNT
    _PAGED_TRACE_COUNT += 1
    x = embed_tokens(params, cfg, tokens[:, None], None,
                     positions=lens[:, None])

    def make_body(moe):
        def body(x, xs):
            lp, pk, pv, rks, rvs = xs
            h = apply_norm(lp["ln1"], x, cfg)
            out, pk, pv = _paged_attn_decode(
                lp["attn"], h, lens, pk, pv, rks, rvs, tables, tails,
                write_block, write_off, cfg, backend)
            x = x + out
            h = apply_norm(lp["ln2"], x, cfg)
            if moe:
                x = x + apply_moe(lp["moe"], h, cfg, capacity_factor=-1.0)
            else:
                x = x + apply_ffn(lp["ffn"], h, cfg)
            return x, (pk, pv)
        return body

    x, (pk, pv) = _scan_dense_moe(params, cfg, x, pool_k, pool_v,
                                  remote_k, remote_v, make_body)
    logits = unembed(params, cfg, x[:, 0])
    return logits, pk, pv


def decode_step_paged(params, cfg: ModelConfig, tokens, lens,
                      pool_k: jax.Array, pool_v: jax.Array,
                      tables, tails, write_block, write_off,
                      remote_pools: Sequence[Tuple[jax.Array, jax.Array]]
                      = (), *, backend: Optional[str] = None
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fixed-shape paged DistAttention decode (dense/moe serving path).

    tokens/lens: [B] (lens = absolute position of the new token);
    pool_k/pool_v: [L, NB, bs, K, hd] — the owner rank's pool, DONATED
    into the step: the caller must drop its handles and continue with
    the returned arrays, which on donating backends are the same device
    buffers updated in place (KV for the new token is written into the
    request's tail block before attention so the token attends to
    itself);
    tables/tails: [P, B, MB] / [P, B] from ``build_local_tables`` over
    (owner pool, *creditor pools) with a bucketed MB;
    write_block/write_off: [B] target (block id, offset) of the new
    token in the OWNER pool; inactive slots use block id NB (dropped);
    remote_pools: creditor [L, NB_p, bs, K, hd] pool pairs, read-only.

    All shapes are independent of context length: growing a request — or
    migrating its blocks between ranks — only edits table/pool *contents*,
    so the step retraces only when the table bucket or rank count changes.
    Returns (logits [B, V], new_pool_k, new_pool_v).
    """
    assert cfg.family in ("dense", "moe"), "only attention archs pool KV"
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    remote_k = tuple(pk for pk, _ in remote_pools)
    remote_v = tuple(pv for _, pv in remote_pools)
    return _decode_step_paged_jit(
        params, jnp.asarray(tokens, jnp.int32), jnp.asarray(lens, jnp.int32),
        pool_k, pool_v, remote_k, remote_v,
        jnp.asarray(tables, jnp.int32), jnp.asarray(tails, jnp.int32),
        jnp.asarray(write_block, jnp.int32),
        jnp.asarray(write_off, jnp.int32), cfg=cfg, backend=backend)


# ===================================================================== #
# Chunked paged prefill (dense/moe): stream a prompt into block pools
# ===================================================================== #
_PREFILL_CHUNK_TRACE_COUNT = 0


def prefill_chunk_trace_count() -> int:
    return _PREFILL_CHUNK_TRACE_COUNT


def _chunk_attn_paged(lp, x, positions, valid, pk, pv, rks, rvs,
                      tables, tails, write_block, write_off, cfg, backend):
    """One layer of the streaming-prefill step for one prompt chunk.

    Every chunk query attends to (a) the tokens already streamed into the
    pools — one paged MicroAttention partial per rank over ``tables``,
    which address exactly the written prefix [0, t0) — and (b) the chunk
    itself under the causal mask. Partials LSE-merge (paper Eq. 3), so
    the result equals dense full-prefix attention. The chunk's KV rows
    landing on THIS rank are scattered into the local pool before the
    paged partial runs; the pre-chunk tables mask them out, so they are
    seen only by the chunk-internal causal partial.
    """
    B, C = x.shape[:2]
    q, k, v = qkv_project(lp, x, positions, cfg)
    pk = pk.at[write_block, write_off].set(k[0].astype(pk.dtype),
                                           mode="drop")
    pv = pv.at[write_block, write_off].set(v[0].astype(pv.dtype),
                                           mode="drop")

    def rank_partial(p, rk, rv):
        # All C chunk queries share the rank's ONE prefix table. On the
        # Pallas path the dedicated prefill kernel streams blocks through
        # VMEM (nothing gathers); the jnp path gathers the prefix rows
        # once and runs a shared-KV partial (transient O(prefix), never
        # O(chunk x prefix)). Both live in kernels.ops.
        from repro.kernels.ops import paged_prefill_attention
        return paged_prefill_attention(q[0], rk, rv, tables[p, 0],
                                       tails[p, 0], backend=backend)

    part = rank_partial(0, pk, pv)
    for p, (rk, rv) in enumerate(zip(rks, rvs), start=1):
        part = combine(part, rank_partial(p, rk, rv))
    o_c, m_c, l_c = micro_attention_prefill(q, k, v, positions, positions,
                                            valid)
    part = combine(part, (o_c[0], m_c[0], l_c[0]))
    out = finalize(part[0], part[2])
    out = out.reshape(B, C, -1).astype(x.dtype) @ lp["wo"]
    return out, pk, pv, k[0], v[0]


@functools.partial(jax.jit, static_argnames=("cfg", "backend"),
                   donate_argnames=("pool_k", "pool_v"))
def _prefill_chunk_paged_jit(params, tokens, positions, valid, last_idx,
                             pool_k, pool_v, remote_k, remote_v,
                             tables, tails, write_block, write_off, *,
                             cfg, backend):
    global _PREFILL_CHUNK_TRACE_COUNT
    _PREFILL_CHUNK_TRACE_COUNT += 1
    x = embed_tokens(params, cfg, tokens, None, positions)

    def make_body(moe):
        def body(x, xs):
            lp, pk, pv, rks, rvs = xs
            h = apply_norm(lp["ln1"], x, cfg)
            out, pk, pv, k, v = _chunk_attn_paged(
                lp["attn"], h, positions, valid, pk, pv, rks, rvs,
                tables, tails, write_block, write_off, cfg, backend)
            x = x + out
            h = apply_norm(lp["ln2"], x, cfg)
            if moe:
                x = x + apply_moe(lp["moe"], h, cfg, capacity_factor=-1.0)
            else:
                x = x + apply_ffn(lp["ffn"], h, cfg)
            return x, (pk, pv, k, v)
        return body

    x, (pk, pv, ks, vs) = _scan_dense_moe(params, cfg, x, pool_k, pool_v,
                                          remote_k, remote_v, make_body)
    logits = unembed(params, cfg, jnp.take(x, last_idx, axis=1))
    return logits, pk, pv, ks, vs


def prefill_chunk_paged(params, cfg: ModelConfig, tokens, t0: int,
                        n_valid: int, pool_k: jax.Array, pool_v: jax.Array,
                        tables, tails, write_block, write_off,
                        remote_pools: Sequence[Tuple[jax.Array, jax.Array]]
                        = (), *, backend: Optional[str] = None):
    """One fixed-shape streaming-prefill step over prompt chunk [t0, t0+C).

    tokens: [C] chunk token ids (the final chunk is zero-padded; only the
    first ``n_valid`` entries are real); pool_k/pool_v: the owner rank's
    [L, NB, bs, K, hd] pool, DONATED — continue with the returned
    arrays (in-place row updates on donating backends), never the
    passed handles; tables/tails: [P, 1, MB] / [P, 1] from ``prefix_tables``
    addressing the already-written tokens [0, t0) on (owner,
    *creditors); write_block/write_off: [C] OWNER-pool target of each
    chunk token (block id NB for rows bound for a creditor or padding —
    dropped); remote_pools: creditor pool pairs, read-only.

    Every shape is a function of (C, P, MB bucket, pool dims) — never of
    the prompt length — so admission compiles are bounded by chunk size
    and peak extra device memory is O(chunk), not O(T). Returns
    (logits [1, V] at the last valid chunk position, new_pool_k,
    new_pool_v, k_chunk [L, C, K, hd], v_chunk) — the chunk KV export is
    what the engine streams to creditor pools for prefix rows.
    """
    assert cfg.family in ("dense", "moe"), "only attention archs pool KV"
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    C = len(tokens)
    positions = t0 + jnp.arange(C, dtype=jnp.int32)[None]
    valid = (jnp.arange(C, dtype=jnp.int32) < n_valid)[None]
    remote_k = tuple(pk for pk, _ in remote_pools)
    remote_v = tuple(pv for _, pv in remote_pools)
    return _prefill_chunk_paged_jit(
        params, jnp.asarray(tokens, jnp.int32)[None], positions, valid,
        jnp.asarray(n_valid - 1, jnp.int32), pool_k, pool_v,
        remote_k, remote_v, jnp.asarray(tables, jnp.int32),
        jnp.asarray(tails, jnp.int32), jnp.asarray(write_block, jnp.int32),
        jnp.asarray(write_off, jnp.int32), cfg=cfg, backend=backend)
