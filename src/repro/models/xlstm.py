"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM (Beck et al. 2024): per head h, matrix memory C in R^{hd x hd}:
    i_t = exp(w_i x_t), f_t = exp(w_f x_t) (log-domain stabilized by m_t)
    C_t = f_t C_{t-1} + i_t v_t k_t^T ;  n_t = f_t n_{t-1} + i_t k_t
    out_t = C_t q_t / max(|n_t . q_t|, 1)

State is O(1) in sequence length — the assigned-pool case where
DistAttention is *inapplicable* (nothing grows, nothing to pool).

sLSTM keeps recurrent (h -> gate) connections so it is inherently
sequential; both train paths use ``jax.lax.scan`` over time.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init


# --------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------- #
class MLstmState(NamedTuple):
    c: jax.Array   # [B, nh, hd, hd] f32
    n: jax.Array   # [B, nh, hd] f32
    m: jax.Array   # [B, nh] f32 (log-domain stabilizer)


def init_mlstm_block(key, cfg: ModelConfig):
    d = cfg.d_model
    up = int(d * cfg.mlstm_proj_factor)
    nh = cfg.num_heads
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d, up, dtype),
        "w_gate": dense_init(ks[1], d, up, dtype),
        "wq": dense_init(ks[2], up, up, dtype),
        "wk": dense_init(ks[3], up, up, dtype),
        "wv": dense_init(ks[4], up, up, dtype),
        "w_if": dense_init(ks[5], up, 2 * nh, dtype),   # input+forget gates
        "b_if": jnp.concatenate([jnp.zeros((nh,)), 3.0 * jnp.ones((nh,))]),
        "gn_scale": jnp.ones((up,), jnp.float32),
        "w_down": dense_init(ks[6], up, d, dtype),
    }


def _mlstm_qkvg(p, x_up, nh):
    B, T, up = x_up.shape
    hd = up // nh
    q = (x_up @ p["wq"]).reshape(B, T, nh, hd).astype(jnp.float32)
    k = (x_up @ p["wk"]).reshape(B, T, nh, hd).astype(jnp.float32) \
        * (hd ** -0.5)
    v = (x_up @ p["wv"]).reshape(B, T, nh, hd).astype(jnp.float32)
    gif = (x_up @ p["w_if"]).astype(jnp.float32) + p["b_if"]
    log_i, log_f = gif[..., :nh], jax.nn.log_sigmoid(gif[..., nh:])
    return q, k, v, log_i, log_f


def mlstm_step(q, k, v, log_i, log_f, state: MLstmState):
    """One recurrent step; all inputs [B, nh, ...] f32."""
    m_new = jnp.maximum(log_f + state.m, log_i)
    i = jnp.exp(log_i - m_new)
    f = jnp.exp(log_f + state.m - m_new)
    c = f[..., None, None] * state.c + i[..., None, None] \
        * (v[..., :, None] * k[..., None, :])           # [B,nh,hd,hd]
    n = f[..., None] * state.n + i[..., None] * k
    num = jnp.einsum("bhij,bhj->bhi", c, q)             # note c stores v k^T
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), 1.0)
    h = num / den[..., None]
    return h, MLstmState(c, n, m_new)


def mlstm_scan(p, x_up, nh, state: MLstmState):
    """Sequential scan over T (baseline; chunkwise-parallel is a perf knob)."""
    B, T, up = x_up.shape
    q, k, v, log_i, log_f = _mlstm_qkvg(p, x_up, nh)

    def body(st, xs):
        qt, kt, vt, lit, lft = xs
        h, st = mlstm_step(qt, kt, vt, lit, lft, st)
        return st, h

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, log_i, log_f))
    state, hs = jax.lax.scan(body, state, xs)
    return jnp.moveaxis(hs, 0, 1).reshape(B, T, up), state   # [B,T,up]


def _group_norm(x, scale, nh, eps=1e-5):
    """Headwise group norm over [..., up]."""
    shp = x.shape
    xh = x.reshape(*shp[:-1], nh, shp[-1] // nh).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(shp) * scale).astype(x.dtype)


def mlstm_state_init(cfg: ModelConfig, batch: int) -> MLstmState:
    up = int(cfg.d_model * cfg.mlstm_proj_factor)
    nh = cfg.num_heads
    hd = up // nh
    return MLstmState(jnp.zeros((batch, nh, hd, hd), jnp.float32),
                      jnp.zeros((batch, nh, hd), jnp.float32),
                      jnp.full((batch, nh), -1e30, jnp.float32))


def apply_mlstm_block(p, x, cfg: ModelConfig, state: MLstmState | None = None,
                      *, decode: bool = False):
    """x: [B, T, d] -> (y [B, T, d], state)."""
    B, T, d = x.shape
    nh = cfg.num_heads
    if state is None:
        state = mlstm_state_init(cfg, B)
    x_up = x @ p["w_up"]
    gate = jax.nn.silu(x @ p["w_gate"])
    if decode:
        q, k, v, log_i, log_f = _mlstm_qkvg(p, x_up, nh)
        h, state = mlstm_step(q[:, 0], k[:, 0], v[:, 0], log_i[:, 0],
                              log_f[:, 0], state)
        h = h.reshape(B, 1, -1)
    else:
        h, state = mlstm_scan(p, x_up, nh, state)
    h = _group_norm(h.astype(x.dtype), p["gn_scale"], nh)
    return (h * gate) @ p["w_down"], state


# --------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------- #
class SLstmState(NamedTuple):
    c: jax.Array   # [B, w] f32 cell
    n: jax.Array   # [B, w] f32 normalizer
    h: jax.Array   # [B, w] f32 hidden (recurrent input)
    m: jax.Array   # [B, w] f32 stabilizer


def init_slstm_block(key, cfg: ModelConfig):
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    up = int(d * 4.0 / 3.0)
    return {
        "w_x": dense_init(ks[0], d, 4 * d, dtype),     # i, f, z, o from x
        "w_h": dense_init(ks[1], d, 4 * d, dtype),     # recurrent
        "b": jnp.zeros((4 * d,), jnp.float32),
        "gn_scale": jnp.ones((d,), jnp.float32),
        "w_ff_i": dense_init(ks[2], d, 2 * up, dtype), # gated FFN (pf 4/3)
        "w_ff_o": dense_init(ks[3], up, d, dtype),
    }


def slstm_step(p, xt, state: SLstmState, d):
    g = (xt @ p["w_x"]).astype(jnp.float32) \
        + (state.h.astype(xt.dtype) @ p["w_h"]).astype(jnp.float32) + p["b"]
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    log_i, log_f = gi, jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + state.m, log_i)
    i = jnp.exp(log_i - m_new)
    f = jnp.exp(log_f + state.m - m_new)
    c = f * state.c + i * jnp.tanh(gz)
    n = f * state.n + i
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
    return h, SLstmState(c, n, h, m_new)


def slstm_state_init(cfg: ModelConfig, batch: int) -> SLstmState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLstmState(z, z, z, jnp.full((batch, d), -1e30, jnp.float32))


def apply_slstm_block(p, x, cfg: ModelConfig, state: SLstmState | None = None,
                      *, decode: bool = False):
    """x: [B, T, d] -> (y, state). Inherently sequential (h -> gates)."""
    B, T, d = x.shape
    if state is None:
        state = slstm_state_init(cfg, B)
    if decode:
        h, state = slstm_step(p, x[:, 0], state, d)
        hs = h[:, None]
    else:
        def body(st, xt):
            h, st = slstm_step(p, xt, st, d)
            return st, h
        state, hs = jax.lax.scan(body, state, jnp.moveaxis(x, 1, 0))
        hs = jnp.moveaxis(hs, 0, 1)
    y = _group_norm(hs.astype(x.dtype), p["gn_scale"], cfg.num_heads)
    ff = y @ p["w_ff_i"]
    a, b = jnp.split(ff, 2, axis=-1)
    return (jax.nn.gelu(a) * b) @ p["w_ff_o"], state
