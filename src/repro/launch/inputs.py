"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape x mesh).

``build_cell(arch, shape, mesh)`` returns everything ``dryrun.py`` needs:
the step function, kwargs of ShapeDtypeStructs, in/out shardings, and
donate hints — with zero device allocation (weak-type-correct stand-ins).
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import (batch_axes, param_specs,
                                        serve_fsdp, serve_pool_axes,
                                        validate_divisibility)
from repro.models.model import init_decode_state, init_params
from repro.serving.sharded_step import (ServeLayout, serve_decode_step,
                                        serve_decode_step_opt,
                                        serve_decode_step_state,
                                        serve_prefill_step,
                                        serve_prefill_step_state)
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import (TrainConfig, TrainState,
                                       init_train_state, train_step)

BLOCK_SIZE = 128            # KV pool block (tokens); MXU-aligned


class Cell(NamedTuple):
    fn: Any                     # callable(**kwargs)
    kwargs: Dict[str, Any]      # ShapeDtypeStructs
    in_shardings: Dict[str, Any]
    out_shardings: Any          # None -> let GSPMD choose
    donate: Tuple[str, ...]     # kwarg names to donate
    meta: Dict[str, Any]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _params_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def mesh_axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _layer_constraints(mesh, pspecs):
    """Per-layer-slice re-pinning functions for each scanned stack.

    Inside a scan body the sliced weights must be constrained back to
    their (FSDP-)sharded spec, otherwise GSPMD hoists one giant
    all-gather of the WHOLE stack out of the loop (TBs at kimi scale).
    """
    out = {}
    for name in ("layers", "dense_layers", "moe_layers", "groups"):
        if not isinstance(pspecs, dict) or name not in pspecs:
            continue
        shardings = jax.tree.map(
            lambda sp: NamedSharding(mesh, P(*tuple(sp)[1:])),
            pspecs[name], is_leaf=lambda x: isinstance(x, P))

        def fn(lp, sh=shardings):
            return jax.tree.map(jax.lax.with_sharding_constraint, lp, sh)
        out[name] = fn
    return out


def _batch_spec(mesh, baxes, n):
    """Shard batch over baxes only when divisible (long_500k has B=1)."""
    sizes = mesh_axis_sizes(mesh)
    total = int(np.prod([sizes[a] for a in baxes]))
    return P(baxes) if n % total == 0 else P()


# ===================================================================== #
def build_train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     *, microbatches: Optional[int] = None,
                     moment_dtype: Optional[str] = None) -> Cell:
    baxes = batch_axes(mesh)
    sizes = mesh_axis_sizes(mesh)
    n_data = int(np.prod([sizes[a] for a in baxes]))
    # 1T-class models store AdamW moments in bf16 to fit HBM.
    if moment_dtype is None:
        moment_dtype = "bfloat16" if cfg.param_count() > 1e11 else "float32"
    if microbatches is None:
        # Keep per-microbatch activations (incl. MoE dispatch buffers)
        # within HBM: ~8 for >=100B-class models, else 1.
        microbatches = 8 if cfg.param_count() > 1e11 else 1
    ep = n_data if (cfg.is_moe and shape.global_batch % n_data == 0) else 0
    acfg = AdamWConfig(moment_dtype=moment_dtype)
    tcfg = TrainConfig(remat=True, microbatches=microbatches,
                       attn_chunk=1024, moe_ep_groups=ep)

    pshapes = _params_shapes(cfg)
    pspecs = validate_divisibility(
        param_specs(cfg, pshapes, fsdp=True, fsdp_axis="data"),
        pshapes, mesh)
    state_shapes = jax.eval_shape(
        lambda: init_train_state(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), pshapes),
            acfg, tcfg))
    state_specs = TrainState(
        params=pspecs,
        opt=type(state_shapes.opt)(P(), pspecs, pspecs),
        ef=None)

    B, S = shape.global_batch, shape.seq_len
    tokens = _sds((B, S + 1), jnp.int32)
    mask = _sds((B, S), jnp.float32)
    kwargs = {"state": state_shapes, "tokens": tokens, "mask": mask}
    in_sh = {"state": _named(mesh, state_specs),
             "tokens": NamedSharding(mesh, P(baxes)),
             "mask": NamedSharding(mesh, P(baxes))}
    if cfg.modality in ("vlm", "audio"):
        kwargs["embeds"] = _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
        in_sh["embeds"] = NamedSharding(mesh, P(baxes))

    fn = functools.partial(train_step, cfg=cfg, tcfg=tcfg, adam_cfg=acfg,
                           layer_constraints=_layer_constraints(mesh,
                                                                pspecs))
    return Cell(fn=fn, kwargs=kwargs, in_shardings=in_sh,
                out_shardings=None, donate=("state",),
                meta={"kind": "train", "batch_axes": baxes,
                      "moment_dtype": moment_dtype})


# ===================================================================== #
def _serve_param_sharding(cfg, mesh):
    pshapes = _params_shapes(cfg)
    fsdp = serve_fsdp(cfg, mesh)
    specs = validate_divisibility(
        param_specs(cfg, pshapes, fsdp=fsdp, fsdp_axis="data"),
        pshapes, mesh)
    return pshapes, specs, fsdp


def build_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Cell:
    baxes = batch_axes(mesh)
    paxes = serve_pool_axes(cfg, mesh)
    layout = ServeLayout(batch_axes=baxes, pool_axes=paxes)
    sizes = mesh_axis_sizes(mesh)
    NP = int(np.prod([sizes[a] for a in paxes]))
    B, S = shape.global_batch, shape.seq_len
    bspec = _batch_spec(mesh, baxes, B)

    pshapes, pspecs, fsdp = _serve_param_sharding(cfg, mesh)
    if cfg.family in ("hybrid", "ssm"):
        # No KV pool: forward + recurrent/window state (DESIGN.md).
        kwargs = {"params": pshapes, "tokens": _sds((B, S), jnp.int32)}
        in_sh = {"params": _named(mesh, pspecs),
                 "tokens": NamedSharding(mesh, bspec)}
        fn = functools.partial(serve_prefill_step_state, cfg=cfg,
                               layout=layout,
                               max_len=min(S, cfg.local_window or 1))
        return Cell(fn=fn, kwargs=kwargs, in_shardings=in_sh,
                    out_shardings=None, donate=(),
                    meta={"kind": "prefill_state", "fsdp": fsdp})
    kwargs = {"params": pshapes}
    in_sh = {"params": _named(mesh, pspecs)}
    if cfg.modality in ("vlm", "audio"):
        kwargs["embeds"] = _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
        in_sh["embeds"] = NamedSharding(mesh, bspec)
        kwargs["tokens"] = None
        in_sh["tokens"] = None
    else:
        kwargs["tokens"] = _sds((B, S), jnp.int32)
        in_sh["tokens"] = NamedSharding(mesh, bspec)

    n_data = int(np.prod([sizes[a] for a in baxes]))
    seq_parallel = os.environ.get("REPRO_SP", "0") == "1"
    fn = functools.partial(serve_prefill_step, cfg=cfg, layout=layout,
                           block_size=BLOCK_SIZE, NP=NP, n_data=n_data,
                           seq_parallel=seq_parallel,
                           layer_constraints=(_layer_constraints(mesh,
                                                                 pspecs)
                                              if fsdp else None))
    kvh = None if "model" in paxes else "model"
    pool_spec = NamedSharding(mesh, P(None, paxes, None, None, kvh, None))
    return Cell(fn=fn, kwargs=kwargs, in_shardings=in_sh,
                out_shardings=(NamedSharding(mesh, bspec), pool_spec,
                               pool_spec),
                donate=(),
                meta={"kind": "prefill", "pool_axes": paxes,
                      "NP": NP, "fsdp": fsdp})


def build_decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      variant: str = "baseline") -> Cell:
    baxes = batch_axes(mesh)
    R, S = shape.global_batch, shape.seq_len
    sizes = mesh_axis_sizes(mesh)
    pshapes, pspecs, fsdp = _serve_param_sharding(cfg, mesh)

    if cfg.family in ("dense", "moe"):
        paxes = serve_pool_axes(cfg, mesh)
        layout = ServeLayout(batch_axes=baxes, pool_axes=paxes)
        NP = int(np.prod([sizes[a] for a in paxes]))
        bs = BLOCK_SIZE
        blocks_per_req = -(-S // bs)
        MB = -(-blocks_per_req // NP) + 1
        NB = max(1, -(-R * blocks_per_req // NP))
        L = cfg.num_layers
        K, hd = cfg.num_kv_heads, cfg.head_dim
        dt = jnp.dtype(cfg.dtype)

        pool = _sds((L, NP, NB, bs, K, hd), dt)
        kvh = None if "model" in paxes else "model"
        pool_spec = NamedSharding(mesh, P(None, paxes, None, None, kvh,
                                          None))
        itab = NamedSharding(mesh, P(paxes))
        kwargs = {
            "params": pshapes, "pool_k": pool, "pool_v": pool,
            "tables": _sds((NP, R, MB), jnp.int32),
            "nblk": _sds((NP, R), jnp.int32),
            "tails": _sds((NP, R), jnp.int32),
            "wblk": _sds((NP, R), jnp.int32),
            "woff": _sds((NP, R), jnp.int32),
            "tokens": _sds((R,), jnp.int32),
            "lens": _sds((R,), jnp.int32),
        }
        in_sh = {"params": _named(mesh, pspecs),
                 "pool_k": pool_spec, "pool_v": pool_spec,
                 "tables": itab, "nblk": itab, "tails": itab,
                 "wblk": itab, "woff": itab,
                 "tokens": NamedSharding(mesh, _batch_spec(mesh, baxes, R)),
                 "lens": NamedSharding(mesh, _batch_spec(mesh, baxes, R))}
        step = (serve_decode_step_opt if variant == "opt"
                else serve_decode_step)
        fn = functools.partial(
            step, cfg=cfg, layout=layout,
            layer_constraints=(_layer_constraints(mesh, pspecs)
                               if fsdp else None))
        return Cell(fn=fn, kwargs=kwargs, in_shardings=in_sh,
                    out_shardings=(NamedSharding(mesh,
                                                 _batch_spec(mesh, baxes, R)),
                                   pool_spec, pool_spec),
                    donate=("pool_k", "pool_v"),
                    meta={"kind": "decode", "pool_axes": paxes, "NP": NP,
                          "NB": NB, "MB": MB, "fsdp": fsdp,
                          "mode": ("seq_model" if "model" in paxes
                                   else "tp_head")})

    # hybrid / ssm: O(1) recurrent state (+ bounded window cache)
    layout = ServeLayout(batch_axes=baxes, pool_axes=baxes)
    bspec = _batch_spec(mesh, baxes, R)
    bax = tuple(bspec)[0] if len(bspec) else None
    state_shapes = jax.eval_shape(
        lambda: init_decode_state(cfg, R, max_len=min(
            S, cfg.local_window or 1)))
    dstate_specs = jax.tree.map(
        lambda s: P(None, bax) if s.ndim >= 2 and s.shape[1] == R
        else (P(bax) if s.ndim >= 1 and s.shape[0] == R else P()),
        state_shapes)
    # mLSTM states are [ng, se-1, B, ...]: batch at axis 2.
    if cfg.family == "ssm":
        dstate_specs = dstate_specs._replace(
            rec={"mlstm": type(state_shapes.rec["mlstm"])(
                *[P(None, None, bax) for _ in state_shapes.rec["mlstm"]]),
                "slstm": type(state_shapes.rec["slstm"])(
                *[P(None, bax) for _ in state_shapes.rec["slstm"]])})
    kwargs = {"params": pshapes, "state": state_shapes,
              "tokens": _sds((R,), jnp.int32)}
    in_sh = {"params": _named(mesh, pspecs),
             "state": _named(mesh, dstate_specs),
             "tokens": NamedSharding(mesh, bspec)}
    fn = functools.partial(serve_decode_step_state, cfg=cfg, layout=layout)
    return Cell(fn=fn, kwargs=kwargs, in_shardings=in_sh,
                out_shardings=None, donate=("state",),
                meta={"kind": "decode_state", "fsdp": fsdp})


# ===================================================================== #
def build_cell(arch: str, shape_name: str, mesh, **kw) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh)
    return build_decode_cell(cfg, shape, mesh, **kw)


def input_specs(arch: str, shape_name: str, mesh) -> Dict[str, Any]:
    """Public API: ShapeDtypeStruct stand-ins for every model input."""
    return build_cell(arch, shape_name, mesh).kwargs
