"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dryrun JSONL records.

  PYTHONPATH=src python -m repro.launch.report results/dryrun_single.jsonl
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

from repro.configs import SHAPES, get_config
from repro.distributed.hardware import V5E


def load(path: str) -> List[dict]:
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    # Keep the LAST record per (arch, shape, mesh) — reruns override.
    uniq: Dict[tuple, dict] = {}
    for r in recs:
        uniq[(r["arch"], r["shape"], r["mesh"])] = r
    return list(uniq.values())


def effective_terms(r: dict):
    """Compute term floored by the analytic model (CPU cost analysis
    undercounts FLOPs inside nested scans — flagged by ratio > 1)."""
    hw = V5E
    t_c_hlo = r["flops_per_device"] / hw.peak_flops_bf16
    t_c_model = r["model_flops"] / (r["chips"] * hw.peak_flops_bf16)
    t_c = max(t_c_hlo, t_c_model)
    t_m = r["bytes_per_device"] / hw.hbm_bw
    t_x = r["collective_bytes_per_device"] / hw.ici_link_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    ideal_c = t_c_model
    ideal_m = r["model_bytes"] / (r["chips"] * hw.hbm_bw)
    frac = min(1.0, max(ideal_c, ideal_m) / max(terms.values()))
    return t_c, t_m, t_x, bottleneck, frac


def fix_note(r: dict, bottleneck: str) -> str:
    cfg = get_config(r["arch"])
    kind = SHAPES[r["shape"]].kind
    if bottleneck == "collective":
        if kind == "train" and cfg.is_moe:
            return ("replace FSDP expert-weight gathers with wide-EP "
                    "token all-to-all (move activations, not experts)")
        if kind == "train":
            return ("overlap FSDP all-gathers with layer compute; "
                    "reduce-scatter grads instead of all-reduce")
        return ("sequence-parallel activations (RS/AG instead of AR) "
                "or DistAttention-prefill context parallelism")
    if bottleneck == "memory":
        if kind == "decode":
            return ("read pool blocks in place (Pallas paged kernel / "
                    "block-scan) instead of materializing a gathered "
                    "KV copy per layer")
        return "larger attention chunks; fuse norm+matmul reads"
    return "increase per-chip tile sizes toward MXU saturation"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "results/dryrun_single.jsonl"
    recs = sorted(load(path), key=lambda r: (r["arch"],
                                             list(SHAPES).index(r["shape"])))
    hdr = ("| arch | shape | chips | t_compute | t_memory | t_collective |"
           " bound | useful-FLOPs | roofline-frac | mem/chip | note |")
    sep = "|" + "---|" * 11
    print(hdr)
    print(sep)
    for r in recs:
        t_c, t_m, t_x, b, frac = effective_terms(r)
        note = fix_note(r, b)
        mem_gb = r.get("memory_analysis", {}).get(
            "argument_size_in_bytes", 0) + r.get("memory_analysis", {}).get(
            "temp_size_in_bytes", 0)
        print(f"| {r['arch']} | {r['shape']} | {r['chips']} "
              f"| {t_c:.2e}s | {t_m:.2e}s | {t_x:.2e}s | **{b}** "
              f"| {min(r['useful_flops_ratio'], 1.0):.2f} "
              f"| {frac:.3f} | {mem_gb / 1e9:.1f}GB | {note} |")


if __name__ == "__main__":
    main()
