"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:
  compute    = HLO_FLOPs        / (chips * peak_FLOP/s)
  memory     = HLO_bytes        / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed from the post-SPMD ``compiled.as_text()`` by summing operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (per-device program -> per-chip bytes).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.hardware import V5E, HardwareSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_OP_RE = re.compile(r"%?[\w.\-]+\s*=\s*(\(?[a-z0-9,\[\]\{\}\s]+\)?)\s+"
                    r"([a-z\-]+)")
_WHILE_RE = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str):
    """{name: [lines]} per HLO computation; also returns the ENTRY name.

    Computation headers look like
      ``%region_0.1_spmd (param: (...)) -> (...) {`` or
      ``ENTRY %main.3_spmd (param.2: f32[4,64], ...) -> f32[4,64] {``;
    bodies are indented and terminated by a lone ``}``.
    """
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_RE.match(s)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if s == "}":
            cur = None
            continue
        comps[cur].append(s)
    return comps, entry


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum OUTPUT-shape bytes of each collective op kind in per-device HLO,
    multiplying ops inside While bodies by their trip count (scan-over-
    layers puts one textual copy of each per-layer collective inside a
    While whose condition compares against constant(L)).

    Result bytes are what each device moves per call up to the ring
    (n-1)/n factor.
    """
    comps, entry = _split_computations(hlo_text)
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    if entry is None:
        return out

    def trip_count(cond_name: str) -> int:
        consts = [int(x) for line in comps.get(cond_name, ())
                  for x in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    seen = set()

    def walk(name: str, mult: int):
        key = (name, mult)
        if key in seen or name not in comps:
            return
        seen.add(key)
        for s in comps[name]:
            m = _OP_RE.match(s)
            if m:
                op = m.group(2)
                hits = [c for c in _COLLECTIVES if op.startswith(c)]
                if hits and not op.endswith("-done"):
                    out[hits[0]] += _shape_bytes(m.group(1)) * mult
            w = _WHILE_RE.search(s)
            if w:
                cond, body = w.group(1), w.group(2)
                walk(body, mult * trip_count(cond))
                continue
            # conditionals / branches (rare in our programs)
            for ref in re.findall(r"(?:branch_computations=\{|to_apply=)"
                                  r"%?([\w.\-]+)", s):
                if ref in comps and any(
                        c in " ".join(comps[ref])
                        for c in _COLLECTIVES):
                    walk(ref, mult)
    walk(entry, 1)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, int]
    peak_memory_per_device: float
    model_flops: float                  # 6ND train / 2ND serve (useful)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_flops_ratio: float = 0.0
    roofline_fraction: float = 0.0      # useful work / dominant-term bound

    model_bytes: float = 0.0            # mandatory traffic (see below)

    def finalize(self, hw: HardwareSpec = V5E):
        self.t_compute = self.flops_per_device / hw.peak_flops_bf16
        self.t_memory = self.bytes_per_device / hw.hbm_bw
        self.t_collective = self.collective_bytes_per_device / hw.ici_link_bw
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        total_hlo_flops = self.flops_per_device * self.chips
        self.useful_flops_ratio = (self.model_flops / total_hlo_flops
                                   if total_hlo_flops else 0.0)
        # Roofline fraction: the step time a perfect implementation needs
        # (max of compute-at-peak on useful FLOPs and HBM-at-peak on
        # mandatory bytes) over the dominant-term time implied by the HLO.
        ideal_c = self.model_flops / (self.chips * hw.peak_flops_bf16)
        ideal_m = self.model_bytes / (self.chips * hw.hbm_bw)
        ideal = max(ideal_c, ideal_m)
        dom = max(terms.values())
        self.roofline_fraction = min(1.0, ideal / dom) if dom else 0.0
        return self


def model_useful_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N_active*D for train, 2*N_active*D forward-only (+ attention)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        base = 6.0 * n * D
    elif shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        base = 2.0 * n * D
    else:                                  # decode: one token per request
        D = shape.global_batch
        base = 2.0 * n * D
    # Attention score/value FLOPs (not in N):
    n_attn = sum(1 for i in range(cfg.num_layers)
                 if cfg.layer_kind(i) == "attn")
    h, hd = cfg.num_heads, cfg.head_dim
    if shape.kind in ("train", "prefill"):
        w = cfg.local_window or shape.seq_len
        ctx = min(w, shape.seq_len)
        att = 4.0 * shape.global_batch * shape.seq_len * ctx / 2 * h * hd \
            * n_attn
        att *= 3 if shape.kind == "train" else 1
    else:
        w = cfg.local_window or shape.seq_len
        ctx = min(w, shape.seq_len)
        att = 4.0 * shape.global_batch * ctx * h * hd * n_attn
    return base + att


def model_mandatory_bytes(cfg: ModelConfig, shape: ShapeConfig,
                          bpe: int = 2) -> float:
    """Minimum HBM traffic a perfect implementation must move (global).

    decode : active params once + the whole KV pool once (+ tiny I/O).
    prefill: params once + KV written once + ~2 activation passes.
    train  : params + grads + moments r/w (8N f32-equiv @4B treated as
             6N*bpe + 8N*4 conservative) + ~4 activation passes w/ remat.
    """
    n = cfg.active_param_count()
    act_bytes = (shape.global_batch * shape.seq_len * cfg.d_model * bpe)
    kv = cfg.kv_bytes_per_token(bpe)
    if shape.kind == "decode":
        ctx = min(cfg.local_window or shape.seq_len, shape.seq_len)
        return n * bpe + shape.global_batch * ctx * kv
    if shape.kind == "prefill":
        return n * bpe + shape.global_batch * shape.seq_len * kv \
            + 2 * act_bytes * cfg.num_layers
    return (2 * n * bpe + 8 * n * 4.0 / 4.0          # p,g bf16 + m,v f32
            + 4 * act_bytes * cfg.num_layers)


def analyze(arch: str, shape: ShapeConfig, mesh_name: str, chips: int,
            cost: dict, mem: dict, hlo_text: str,
            cfg: ModelConfig) -> RooflineReport:
    coll = collective_bytes_from_hlo(hlo_text)
    rep = RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_device=float(sum(coll.values())),
        collective_breakdown=coll,
        peak_memory_per_device=float(mem.get("temp_size_in_bytes", 0)
                                     + mem.get("argument_size_in_bytes", 0)
                                     + mem.get("output_size_in_bytes", 0)),
        model_flops=model_useful_flops(cfg, shape),
        model_bytes=model_mandatory_bytes(cfg, shape),
    )
    return rep.finalize()


def report_row(r: RooflineReport) -> str:
    return (f"{r.arch},{r.shape},{r.mesh},{r.chips},"
            f"{r.flops_per_device:.3e},{r.bytes_per_device:.3e},"
            f"{r.collective_bytes_per_device:.3e},"
            f"{r.t_compute:.3e},{r.t_memory:.3e},{r.t_collective:.3e},"
            f"{r.bottleneck},{r.useful_flops_ratio:.3f},"
            f"{r.roofline_fraction:.3f},{r.peak_memory_per_device:.3e}")


REPORT_HEADER = ("arch,shape,mesh,chips,flops_dev,bytes_dev,coll_bytes_dev,"
                 "t_compute,t_memory,t_collective,bottleneck,"
                 "useful_flops_ratio,roofline_fraction,peak_mem_dev")
