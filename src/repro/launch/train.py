"""Training launcher: real steps on local devices, or AOT-compile the
production-mesh program (CPU host) for any assigned arch.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --steps 20 --seq 256 --batch 4 --smoke          # real CPU steps
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --aot
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--aot", action="store_true",
                    help="AOT-compile the production train_step instead "
                         "of running (sets 512 fake devices)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    if args.aot:
        import os
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   " --xla_force_host_platform_device_count"
                                   "=512")
        from repro.launch.dryrun import run_cell
        run_cell(args.arch, "train_4k")
        return

    import jax
    import jax.numpy as jnp
    from repro.checkpoint import Checkpointer, save_train_state
    from repro.configs import get_config, get_smoke_config
    from repro.models.model import init_params
    from repro.training.data import DataConfig, batch_for_step
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_step import (TrainConfig, init_train_state,
                                           train_step)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")
    params = init_params(jax.random.PRNGKey(0), cfg)
    acfg = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                       total_steps=args.steps)
    tcfg = TrainConfig(remat=True)
    state = init_train_state(params, acfg, tcfg)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, seed=0)
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    step_fn = jax.jit(lambda s, t, m: train_step(
        s, t, m, cfg=cfg, tcfg=tcfg, adam_cfg=acfg))
    t0 = time.time()
    for step in range(args.steps):
        toks, mask = batch_for_step(dc, step)
        state, out = step_fn(state, jnp.asarray(toks), jnp.asarray(mask))
        if step % max(1, args.steps // 10) == 0:
            print(f"step {step:5d}  loss {float(out['loss']):.4f}  "
                  f"gnorm {float(out['grad_norm']):.3f}  "
                  f"{time.time() - t0:.0f}s")
        if ckpt and step and step % 50 == 0:
            save_train_state(ckpt, step, state)
    print(f"done: final loss {float(out['loss']):.4f}")


if __name__ == "__main__":
    main()
