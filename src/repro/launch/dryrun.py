import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: jit with production shardings, .lower(**input_specs),
.compile(); print memory_analysis() (proves the per-device footprint) and
cost_analysis() (FLOPs/bytes for the roofline). Failures here are bugs in
the sharding config.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--out report.jsonl]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.inputs import build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze, report_row, REPORT_HEADER


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, save_hlo: str | None = None,
             **cell_kw):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.devices.size
    cell = build_cell(arch, shape_name, mesh, **cell_kw)
    argnames = list(cell.kwargs)
    donate = tuple(argnames.index(n) for n in cell.donate)

    def wrapped(*args):
        return cell.fn(**dict(zip(argnames, args)))

    jitted = jax.jit(
        wrapped,
        in_shardings=tuple(cell.in_shardings.get(n) for n in argnames),
        out_shardings=cell.out_shardings,
        donate_argnums=donate)

    t0 = time.time()
    with mesh:
        lowered = jitted.lower(*[cell.kwargs[n] for n in argnames])
        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    mem_d = {k: getattr(mem, k) for k in
             ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes")} \
        if mem is not None else {}
    cost_d = dict(cost) if cost else {}
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    rep = analyze(arch, SHAPES[shape_name], mesh_name, chips, cost_d,
                  mem_d, hlo, get_config(arch))
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
              f"compile {t1 - t0:.1f}s")
        print(f"  memory_analysis: {json.dumps(mem_d)}")
        print(f"  cost_analysis: flops={cost_d.get('flops', 0):.3e} "
              f"bytes={cost_d.get('bytes accessed', 0):.3e}")
        print(f"  collectives: {rep.collective_breakdown}")
        print(f"  roofline: compute={rep.t_compute:.3e}s "
              f"memory={rep.t_memory:.3e}s "
              f"collective={rep.t_collective:.3e}s "
              f"-> {rep.bottleneck}-bound "
              f"(useful-flops ratio {rep.useful_flops_ratio:.2f}, "
              f"roofline fraction {rep.roofline_fraction:.2f})")
    return rep, mem_d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL reports here")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "opt"],
                    help="decode-step implementation (§Perf)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    print(REPORT_HEADER)
    failures = []
    for multi_pod in meshes:
        for a, s in cells:
            try:
                kw = ({"variant": args.variant}
                      if SHAPES[s].kind == "decode" else {})
                rep, mem_d = run_cell(a, s, multi_pod=multi_pod,
                                      save_hlo=args.save_hlo, **kw)
                print(report_row(rep))
                if args.out:
                    with open(args.out, "a") as f:
                        rec = dataclasses.asdict(rep)
                        rec["memory_analysis"] = mem_d
                        f.write(json.dumps(rec) + "\n")
            except Exception as e:
                failures.append((a, s, multi_pod, repr(e)))
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print("\nall cells compiled OK")


if __name__ == "__main__":
    main()
