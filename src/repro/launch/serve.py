"""Serving launcher: drive an Infinite-LLM ``LLMServer`` open-loop on
synthetic traffic (smoke configs, CPU) or AOT-compile the production
serve step.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b \
      --instances 3 --requests 8
  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-15b \
      --aot --shape decode_32k
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--long-frac", type=float, default=0.2,
                    help="fraction of requests that exceed one instance")
    ap.add_argument("--aot", action="store_true")
    ap.add_argument("--shape", default="decode_32k",
                    choices=["prefill_32k", "decode_32k", "long_500k"])
    args = ap.parse_args()

    if args.aot:
        import os
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   " --xla_force_host_platform_device_count"
                                   "=512")
        from repro.launch.dryrun import run_cell
        run_cell(args.arch, args.shape)
        return

    import jax
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving import (Arrival, LLMServer, SamplingParams,
                               ServingConfig)

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = LLMServer(params, cfg,
                       ServingConfig.smoke(n_instances=args.instances))
    # Open-loop synthetic traffic: Poisson-ish arrivals over ~1s.
    rng = np.random.default_rng(0)
    arrivals = []
    for i in range(args.requests):
        n = int(rng.integers(40, 70)) if rng.random() < args.long_frac \
            else int(rng.integers(4, 20))
        arrivals.append(Arrival(
            at=float(rng.uniform(0.0, 1.0)),
            prompt=rng.integers(0, cfg.vocab_size, size=n).tolist(),
            sampling=SamplingParams(max_new_tokens=args.max_new)))
    t0 = time.time()
    stats = server.run(arrivals)
    dt = time.time() - t0
    st = server.cluster.throughput_stats
    print(f"{stats['finished']:.0f}/{len(arrivals)} finished, "
          f"{stats['tokens']:.0f} tokens ({dt:.1f}s wall on CPU); "
          f"ttft_p50={stats['ttft_p50'] * 1e3:.0f}ms "
          f"ttft_p99={stats['ttft_p99'] * 1e3:.0f}ms "
          f"tbt_p99={stats['tbt_p99'] * 1e3:.0f}ms")
    print(f"KV moved {st['kv_moved_bytes'] / 1024:.1f} KiB; "
          f"query/merge traffic {st['query_shipped_bytes'] / 1024:.1f} KiB")


if __name__ == "__main__":
    main()
