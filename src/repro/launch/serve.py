"""Serving launcher: run an Infinite-LLM cluster on synthetic traffic
(smoke configs, CPU) or AOT-compile the production serve step.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b \
      --instances 3 --requests 8
  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-15b \
      --aot --shape decode_32k
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--long-frac", type=float, default=0.2,
                    help="fraction of requests that exceed one instance")
    ap.add_argument("--aot", action="store_true")
    ap.add_argument("--shape", default="decode_32k",
                    choices=["prefill_32k", "decode_32k", "long_500k"])
    args = ap.parse_args()

    if args.aot:
        import os
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   " --xla_force_host_platform_device_count"
                                   "=512")
        from repro.launch.dryrun import run_cell
        run_cell(args.arch, args.shape)
        return

    import jax
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serving import Cluster, Request, RequestState, \
        SamplingParams

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    cl = Cluster(params, cfg, n_instances=args.instances, max_batch=3,
                 max_local_len=32, pool_blocks=48, block_size=8,
                 move_chunk_tokens=8)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        n = int(rng.integers(40, 70)) if rng.random() < args.long_frac \
            else int(rng.integers(4, 20))
        reqs.append(Request(
            prompt=list(rng.integers(0, cfg.vocab_size, size=n)),
            sampling=SamplingParams(max_new_tokens=args.max_new)))
        cl.submit(reqs[-1])
    t0 = time.time()
    steps = cl.run_until_done(max_steps=500)
    dt = time.time() - t0
    done = sum(r.state == RequestState.FINISHED for r in reqs)
    toks = sum(len(r.output) for r in reqs)
    st = cl.throughput_stats
    print(f"{done}/{len(reqs)} finished, {toks} tokens in {steps} steps "
          f"({dt:.1f}s wall on CPU)")
    print(f"KV moved {st['kv_moved_bytes'] / 1024:.1f} KiB; "
          f"query/merge traffic {st['query_shipped_bytes'] / 1024:.1f} KiB")


if __name__ == "__main__":
    main()
