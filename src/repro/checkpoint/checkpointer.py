"""Sharded, atomic checkpoint/restore with a manifest (fault tolerance).

Layout:  <dir>/step_<N>/
            manifest.json        tree structure + leaf metadata + step
            leaf_<i>.npy         one file per pytree leaf (locally sharded
                                 arrays are saved per-shard on real
                                 multi-host runs; on one host, whole)
         <dir>/step_<N>.tmp...   staged, then os.rename -> atomic commit.

Restart picks the highest complete step (manifest present). A crash
mid-save leaves only a .tmp directory, which is ignored and reaped.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: Any) -> str:
        paths, leaves, _ = _flatten_with_paths(tree)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for i, (p, leaf) in enumerate(zip(paths, leaves)):
            arr = np.asarray(leaf)
            true_dtype = str(arr.dtype)
            if arr.dtype == jnp.bfloat16:       # numpy can't serialize bf16
                arr = arr.view(np.uint16)
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
            manifest["leaves"].append(
                {"i": i, "path": p, "shape": list(arr.shape),
                 "dtype": true_dtype})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic commit
        self._gc()
        return final

    def restore(self, step: int, like: Any) -> Any:
        """Restore into the structure of ``like`` (shapes must match)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        paths, leaves, treedef = _flatten_with_paths(like)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        out = []
        for p, leaf in zip(paths, leaves):
            e = by_path[p]
            arr = np.load(os.path.join(d, f"leaf_{e['i']}.npy"))
            dtype = jnp.dtype(e["dtype"])
            if dtype == jnp.bfloat16 and arr.dtype == np.uint16:
                arr = arr.view(jnp.bfloat16)
            assert list(arr.shape) == list(np.shape(leaf)), \
                f"shape mismatch at {p}"
            out.append(jnp.asarray(arr, dtype=dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------ #
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(self.dir, name,
                                                    "manifest.json")):
                out.append(int(name[5:]))
        return sorted(out)

    def latest(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
        for name in os.listdir(self.dir):           # reap crashed saves
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)


def save_train_state(ckpt: Checkpointer, step: int, state) -> str:
    return ckpt.save(step, {"params": state.params, "opt": state.opt,
                            "ef": state.ef})


def restore_train_state(ckpt: Checkpointer, step: int, like):
    from repro.training.train_step import TrainState
    tree = ckpt.restore(step, {"params": like.params, "opt": like.opt,
                               "ef": like.ef})
    return TrainState(tree["params"], type(like.opt)(*tree["opt"]),
                      tree["ef"])


def latest_step(directory: str) -> Optional[int]:
    return Checkpointer(directory).latest()
