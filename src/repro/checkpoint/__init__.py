from repro.checkpoint.checkpointer import (Checkpointer, latest_step,
                                           restore_train_state,
                                           save_train_state)

__all__ = ["Checkpointer", "latest_step", "restore_train_state",
           "save_train_state"]
