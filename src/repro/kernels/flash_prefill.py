"""Pallas TPU kernel: causal (optionally sliding-window) flash attention.

Used for the prefill phase and training attention. Online-softmax over KV
tiles; fp32 accumulators in VMEM scratch.

TPU mapping:
  grid = (B, H, nq, nk) with nk innermost/sequential; q tile (bq, D) and
  KV tile (bk, D) are MXU-shaped (128 x 128-padded-D by default).
  GQA: the kv-head block index is h // (H // K) — computed in the
  BlockSpec index map, so each query head streams only its group's KV.
  Causal skip: tiles entirely above the diagonal (and entirely outside
  the sliding window) are skipped with ``pl.when`` — ~2x fewer tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s,
            *, bq: int, bk: int, nk: int, seq: int, scale: float,
            window: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q_lo = iq * bq
    k_lo = ik * bk
    # Tile-level causal/window culling (static per grid step).
    live = k_lo <= q_lo + bq - 1
    if window:
        live = jnp.logical_and(live, k_lo + bk - 1 > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)            # [bq, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bk, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qp = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = (kp <= qp) & (kp < seq)
        if window:
            ok = ok & (kp > qp - window)
        s = jnp.where(ok, s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)                          # [bq]
        m_old = m_s[:, 0]
        m_new = jnp.maximum(m_old, m_blk)
        alpha = jnp.where(jnp.isneginf(m_old), 0.0, jnp.exp(m_old - m_new))
        p = jnp.exp(s - jnp.where(jnp.isneginf(m_new), 0.0, m_new)[:, None])
        p = jnp.where(ok, p, 0.0)
        l_s[:, 0] = l_s[:, 0] * alpha + jnp.sum(p, -1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[:, 0] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_s[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


def flash_prefill_kernel(
    q: jax.Array,          # [B, S, H, D] (S and D pre-padded by ops.py)
    k: jax.Array,          # [B, S, K, D]
    v: jax.Array,
    *,
    seq: int,              # true (unpadded) sequence length
    scale: float,
    window: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
):
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    bq = min(bq, S)
    bk = min(bk, S)
    nq, nk = S // bq, S // bk

    kernel = functools.partial(_kernel, bq=bq, bk=bk, nk=nk, seq=seq,
                               scale=scale, window=window)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, S, H, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
