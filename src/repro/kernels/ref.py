"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_prefill_ref(q, k, v, *, scale=None, window=0):
    """Causal (optionally sliding-window) attention.

    q: [B, S, H, D]; k, v: [B, S, K, D] -> [B, S, H, D].
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    if scale is None:
        scale = D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, S, K, G, D)
    s = jnp.einsum("btkgd,bskd->btkgs", qf, k.astype(jnp.float32)) * scale
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    ok = kp <= qp
    if window:
        ok = ok & (kp > qp - window)
    s = jnp.where(ok[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("btkgs,bskd->btkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def paged_prefill_micro_attention_ref(q, pool_k, pool_v, table, nblk,
                                      last_len, *, scale=None):
    """Prefill-chunk MicroAttention over a local paged pool.

    q:        [C, H, D]       chunk queries (positions all >= the prefix)
    pool_k/v: [NB, bs, K, D]  this rank's block pool
    table:    [MB] int32      the request's block ids, -1 padded — ONE
                              table shared by every chunk query, covering
                              exactly the already-written prefix [0, t0)
    nblk:     [] int32        number of valid table slots
    last_len: [] int32        valid tokens in the prefix's final block
    Returns (o [C,H,D] f32 unnormalized, m [C,H] f32, l [C,H] f32).
    No causal mask: every addressed token precedes every chunk query.
    """
    C, H, D = q.shape
    NB, bs, K, _ = pool_k.shape
    MB = table.shape[0]
    if scale is None:
        scale = D ** -0.5
    safe = jnp.maximum(table, 0)
    k = pool_k[safe].reshape(MB * bs, K, D)
    v = pool_v[safe].reshape(MB * bs, K, D)
    j = jnp.arange(MB)
    is_last = (j == nblk - 1)[:, None]
    within = jnp.arange(bs)[None, :]
    tok_ok = jnp.where(is_last, within < last_len, True)
    mask = ((table >= 0)[:, None] & tok_ok).reshape(MB * bs)

    G = H // K
    qc = q.astype(k.dtype).reshape(C, K, G, D)
    s = jnp.einsum("ckgd,skd->ckgs", qc, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - jnp.where(jnp.isneginf(m), 0.0, m)[..., None])
    p = jnp.where(mask[None, None, None, :], p, 0.0)
    o = jnp.einsum("ckgs,skd->ckgd", p.astype(k.dtype), v,
                   preferred_element_type=jnp.float32)
    l = jnp.sum(p, axis=-1)
    return (o.reshape(C, H, D), m.reshape(C, H), l.reshape(C, H))


def paged_micro_attention_ref(q, pool_k, pool_v, table, nblk, last_len,
                              *, scale=None):
    """DistAttention MicroAttention over a local paged pool (decode).

    q:        [R, H, D]       one query token per request
    pool_k/v: [NB, bs, K, D]  this rank's block pool
    table:    [R, MB] int32   local block ids, -1 padded
    nblk:     [R] int32       number of valid blocks per request
    last_len: [R] int32       valid tokens in each request's final block
    Returns (o [R,H,D] f32 unnormalized, m [R,H] f32, l [R,H] f32) — the
    MicroAttention partial (paper Eq. 2), mergeable across ranks.
    """
    R, H, D = q.shape
    NB, bs, K, _ = pool_k.shape
    MB = table.shape[1]
    if scale is None:
        scale = D ** -0.5
    safe = jnp.maximum(table, 0)
    k = pool_k[safe].reshape(R, MB * bs, K, D)
    v = pool_v[safe].reshape(R, MB * bs, K, D)
    j = jnp.arange(MB)[None, :].repeat(R, 0)
    block_valid = table >= 0
    within = jnp.arange(bs)[None, None, :]
    is_last = (j == nblk[:, None] - 1)[..., None]
    tok_ok = jnp.where(is_last, within < last_len[:, None, None], True)
    mask = (block_valid[..., None] & tok_ok).reshape(R, MB * bs)

    G = H // K
    # f32 accumulation WITHOUT materializing f32 copies of the pool
    # (preferred_element_type on the dots; p cast to the storage dtype).
    qc = q.astype(k.dtype).reshape(R, K, G, D)
    s = jnp.einsum("rkgd,rskd->rkgs", qc, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - jnp.where(jnp.isneginf(m), 0.0, m)[..., None])
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    o = jnp.einsum("rkgs,rskd->rkgd", p.astype(k.dtype), v,
                   preferred_element_type=jnp.float32)
    l = jnp.sum(p, axis=-1)
    return (o.reshape(R, H, D), m.reshape(R, H), l.reshape(R, H))
