"""Pallas TPU kernel: paged DistAttention MicroAttention (decode).

One query token per request attends over this rank's *local* slice of the
paged KV pool, selected by a scalar-prefetched block table, producing the
unnormalized MicroAttention partial ``(o, m, l)`` (paper Eq. 2). Partials
from all ranks merge with collectives (``repro.core.distattn``).

TPU mapping:
  grid = (R, MB): requests x local-table slots; MB is the innermost,
  sequential dimension so the online-softmax accumulator lives in VMEM
  scratch across slots.
  BlockSpec prefetches pool block ``table[r, j]`` directly from HBM into
  VMEM — the kernel never touches blocks that are not in the table (and
  ``pl.when`` skips -1 slots entirely).
  Tiles: KV block (bs, D) with bs=block_size (128 default) and D padded
  to a lane multiple of 128 by the ops.py wrapper — (q @ k^T) is a
  [G, D] x [D, bs] MXU matmul per kv-head group, (p @ v) is [G, bs] x
  [bs, D]. fp32 accumulation throughout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(table_ref, nblk_ref, tail_ref,          # scalar prefetch (SMEM)
            q_ref, k_ref, v_ref,                    # VMEM inputs
            o_ref, m_ref, l_ref,                    # VMEM outputs
            acc, m_s, l_s,                          # VMEM scratch
            *, bs: int, K: int, G: int, scale: float, mb: int):
    r = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    block_id = table_ref[r, j]

    @pl.when(block_id >= 0)
    def _compute():
        # Valid-token limit: only the request's LAST local slot is partial.
        limit = jnp.where(j == nblk_ref[r] - 1, tail_ref[r], bs)
        valid = (jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
                 < limit)                                    # [1, bs]
        for kh in range(K):                                  # unrolled
            qk = q_ref[0, kh * G:(kh + 1) * G, :].astype(jnp.float32)
            kb = k_ref[0, :, kh, :].astype(jnp.float32)      # [bs, D]
            vb = v_ref[0, :, kh, :].astype(jnp.float32)
            s = jax.lax.dot_general(
                qk, kb, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale  # [G, bs]
            s = jnp.where(valid, s, NEG_INF)
            m_blk = jnp.max(s, axis=-1)                      # [G]
            m_old = m_s[0, kh * G:(kh + 1) * G]
            m_new = jnp.maximum(m_old, m_blk)
            alpha = jnp.where(jnp.isneginf(m_old), 0.0,
                              jnp.exp(m_old - m_new))
            p = jnp.exp(s - jnp.where(jnp.isneginf(m_new), 0.0,
                                      m_new)[:, None])
            p = jnp.where(valid, p, 0.0)                     # [G, bs]
            l_new = l_s[0, kh * G:(kh + 1) * G] * alpha + jnp.sum(p, -1)
            pv = jax.lax.dot_general(
                p, vb, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)          # [G, D]
            acc[kh * G:(kh + 1) * G, :] = (
                acc[kh * G:(kh + 1) * G, :] * alpha[:, None] + pv)
            m_s[0, kh * G:(kh + 1) * G] = m_new
            l_s[0, kh * G:(kh + 1) * G] = l_new

    @pl.when(j == mb - 1)
    def _finalize():
        o_ref[0] = acc[...]
        m_ref[0] = m_s[0]
        l_ref[0] = l_s[0]


def paged_micro_attention_kernel(
    q: jax.Array,          # [R, H, D]
    pool_k: jax.Array,     # [NB, bs, K, D]
    pool_v: jax.Array,
    table: jax.Array,      # [R, MB] int32 (-1 padded, sequence order)
    nblk: jax.Array,       # [R] int32 valid slots per request
    tail_len: jax.Array,   # [R] int32 valid tokens in last local slot
    *,
    scale: float,
    interpret: bool = True,
):
    R, H, D = q.shape
    NB, bs, K, _ = pool_k.shape
    MB = table.shape[1]
    G = H // K

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(R, MB),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda r, j, t, n, tl: (r, 0, 0)),
            pl.BlockSpec((1, bs, K, D),
                         lambda r, j, t, n, tl: (jnp.maximum(t[r, j], 0),
                                                 0, 0, 0)),
            pl.BlockSpec((1, bs, K, D),
                         lambda r, j, t, n, tl: (jnp.maximum(t[r, j], 0),
                                                 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, D), lambda r, j, t, n, tl: (r, 0, 0)),
            pl.BlockSpec((1, H), lambda r, j, t, n, tl: (r, 0)),
            pl.BlockSpec((1, H), lambda r, j, t, n, tl: (r, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, D), jnp.float32),
            pltpu.VMEM((1, H), jnp.float32),
            pltpu.VMEM((1, H), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, bs=bs, K=K, G=G, scale=scale, mb=MB)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((R, H, D), jnp.float32),
            jax.ShapeDtypeStruct((R, H), jnp.float32),
            jax.ShapeDtypeStruct((R, H), jnp.float32),
        ],
        interpret=interpret,
    )(table, nblk, tail_len, q, pool_k, pool_v)
