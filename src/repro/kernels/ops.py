"""jit'd wrappers around the Pallas kernels: padding, dtype, auto-interpret.

Head dim is padded to a 128-lane multiple (zero-padding leaves q.k and
p.v unchanged, the softmax scale always uses the TRUE head dim), sequence
to the tile size. ``interpret`` defaults to True off-TPU so the same code
validates on CPU and compiles natively on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_prefill import flash_prefill_kernel
from repro.kernels.micro_attn_decode import paged_micro_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_last(x, mult):
    d = x.shape[-1]
    pad = (-d) % mult
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def _pad_axis(x, axis, mult):
    d = x.shape[axis]
    pad = (-d) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("scale", "window", "bq", "bk",
                                             "interpret"))
def flash_prefill(q, k, v, *, scale=None, window=0, bq=128, bk=128,
                  interpret=None):
    """Causal flash attention. q [B,S,H,D], k/v [B,S,K,D] -> [B,S,H,D]."""
    B, S, H, D = q.shape
    if scale is None:
        scale = D ** -0.5
    if interpret is None:
        interpret = not _on_tpu()
    qp = _pad_axis(_pad_last(q, 128), 1, bq)
    kp = _pad_axis(_pad_last(k, 128), 1, bq)
    vp = _pad_axis(_pad_last(v, 128), 1, bq)
    out = flash_prefill_kernel(qp, kp, vp, seq=S, scale=scale, window=window,
                               bq=bq, bk=bk, interpret=interpret)
    return out[:, :S, :, :D]


def paged_micro_attention_jnp(q, pool_k, pool_v, table, tail_len, *,
                              scale=None):
    """Pure-jnp paged MicroAttention partial — the gather fallback.

    Same contract as ``paged_micro_attention`` but built from a plain
    gather + ``micro_attention_decode`` so it fuses into surrounding jit
    code (e.g. the serving decode scan) on any backend, no Pallas needed.
    """
    from repro.core.distattn import gather_local_kv, local_mask_from_table
    from repro.core.online_softmax import micro_attention_decode
    bs = pool_k.shape[1]
    k, v = gather_local_kv(pool_k, pool_v, table)
    mask = local_mask_from_table(table, bs, tail_len)
    return micro_attention_decode(q, k, v, mask, scale=scale)


@functools.partial(jax.jit, static_argnames=("scale", "interpret", "backend"))
def paged_micro_attention(q, pool_k, pool_v, table, tail_len, *,
                          scale=None, interpret=None, backend=None):
    """Paged DistAttention MicroAttention partial (decode).

    q [R,H,D]; pool_k/v [NB,bs,K,D]; table [R,MB] (-1 padded, seq order);
    tail_len [R] valid tokens in each request's LAST local slot.
    ``backend``: "pallas" (kernel; interpret mode off-TPU) or "jnp" (pure
    gather fallback); None picks pallas on TPU and jnp elsewhere.
    Returns (o [R,H,D] f32 unnormalized, m [R,H] f32, l [R,H] f32).
    """
    R, H, D = q.shape
    if scale is None:
        scale = D ** -0.5
    if backend is None:
        backend = "pallas" if (_on_tpu() or interpret is not None) else "jnp"
    if backend == "jnp":
        return paged_micro_attention_jnp(q, pool_k, pool_v,
                                         table.astype(jnp.int32),
                                         tail_len.astype(jnp.int32),
                                         scale=scale)
    if interpret is None:
        interpret = not _on_tpu()
    nblk = jnp.sum(table >= 0, axis=1).astype(jnp.int32)
    qp = _pad_last(q, 128)
    kp = _pad_last(pool_k, 128)
    vp = _pad_last(pool_v, 128)
    o, m, l = paged_micro_attention_kernel(
        qp, kp, vp, table.astype(jnp.int32), nblk,
        tail_len.astype(jnp.int32), scale=scale, interpret=interpret)
    return o[:, :, :D], m, l
