"""jit'd wrappers around the Pallas kernels: padding, dtype, auto-interpret.

Head dim is padded to a 128-lane multiple (zero-padding leaves q.k and
p.v unchanged, the softmax scale always uses the TRUE head dim), sequence
to the tile size. ``interpret`` defaults to True off-TPU so the same code
validates on CPU and compiles natively on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_prefill import flash_prefill_kernel
from repro.kernels.micro_attn_decode import paged_micro_attention_kernel
from repro.kernels.micro_attn_prefill import \
    paged_prefill_micro_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_last(x, mult):
    d = x.shape[-1]
    pad = (-d) % mult
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def _pad_axis(x, axis, mult):
    d = x.shape[axis]
    pad = (-d) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("scale", "window", "bq", "bk",
                                             "interpret"))
def flash_prefill(q, k, v, *, scale=None, window=0, bq=128, bk=128,
                  interpret=None):
    """Causal flash attention. q [B,S,H,D], k/v [B,S,K,D] -> [B,S,H,D]."""
    B, S, H, D = q.shape
    if scale is None:
        scale = D ** -0.5
    if interpret is None:
        interpret = not _on_tpu()
    qp = _pad_axis(_pad_last(q, 128), 1, bq)
    kp = _pad_axis(_pad_last(k, 128), 1, bq)
    vp = _pad_axis(_pad_last(v, 128), 1, bq)
    out = flash_prefill_kernel(qp, kp, vp, seq=S, scale=scale, window=window,
                               bq=bq, bk=bk, interpret=interpret)
    return out[:, :S, :, :D]


def paged_micro_attention_jnp(q, pool_k, pool_v, table, tail_len, *,
                              scale=None):
    """Pure-jnp paged MicroAttention partial — the gather fallback.

    Same contract as ``paged_micro_attention`` but built from a plain
    gather + ``micro_attention_decode`` so it fuses into surrounding jit
    code (e.g. the serving decode scan) on any backend, no Pallas needed.
    """
    from repro.core.distattn import gather_local_kv, local_mask_from_table
    from repro.core.online_softmax import micro_attention_decode
    bs = pool_k.shape[1]
    k, v = gather_local_kv(pool_k, pool_v, table)
    mask = local_mask_from_table(table, bs, tail_len)
    return micro_attention_decode(q, k, v, mask, scale=scale)


def paged_prefill_attention_jnp(q, pool_k, pool_v, table, tail_len, *,
                                scale=None):
    """Pure-jnp prefill-chunk paged partial — the gather fallback.

    All C chunk queries share the rank's ONE table, so the prefix rows
    are gathered once ([S, K, D]) and a shared-KV partial runs —
    transient stays O(prefix), never O(chunk x prefix). Fuses into
    surrounding jit code (the streaming-prefill scan) on any backend.
    """
    from repro.core.distattn import gather_local_kv, local_mask_from_table
    from repro.core.online_softmax import micro_attention_prefill
    bs = pool_k.shape[1]
    k, v = gather_local_kv(pool_k, pool_v, table[None])    # [1, S, K, D]
    valid = local_mask_from_table(table[None], bs, tail_len[None])
    # Every addressed token precedes every chunk query: q_pos=1 > kv_pos=0
    # keeps the causal test vacuously true for all (query, kv) pairs.
    q_pos = jnp.ones((1, q.shape[0]), jnp.int32)
    kv_pos = jnp.zeros_like(valid, jnp.int32)
    o, m, l = micro_attention_prefill(q[None], k, v, q_pos, kv_pos, valid,
                                      scale=scale)
    return o[0], m[0], l[0]


@functools.partial(jax.jit, static_argnames=("scale", "interpret", "backend"))
def paged_prefill_attention(q, pool_k, pool_v, table, tail_len, *,
                            scale=None, interpret=None, backend=None):
    """Paged DistAttention MicroAttention partial (prefill chunk).

    q [C,H,D] — one chunk of query rows, all positioned AFTER the
    addressed prefix; pool_k/v [NB,bs,K,D]; table [MB] (-1 padded, seq
    order) shared by every query; tail_len [] valid tokens in the
    prefix's final block. ``backend``: "pallas" (kernel; interpret mode
    off-TPU) or "jnp" (pure gather fallback); None picks pallas on TPU
    and jnp elsewhere. Returns (o [C,H,D] f32 unnormalized, m [C,H] f32,
    l [C,H] f32) — LSE-mergeable with the chunk-internal causal partial.
    """
    C, H, D = q.shape
    NB, bs, K, _ = pool_k.shape
    if scale is None:
        scale = D ** -0.5
    table = table.astype(jnp.int32)
    tail_len = tail_len.astype(jnp.int32)
    if backend is None:
        backend = "pallas" if (_on_tpu() or interpret is not None) else "jnp"
    if backend == "jnp":
        return paged_prefill_attention_jnp(q, pool_k, pool_v, table,
                                           tail_len, scale=scale)
    if interpret is None:
        interpret = not _on_tpu()
    G = H // K
    # kv-head-major query layout: each head group is a contiguous
    # [C*G, D] slab the kernel feeds to the MXU; rows padded to a
    # sublane multiple (padded rows compute garbage, sliced off below).
    qr = q.reshape(C, K, G, D).transpose(1, 0, 2, 3).reshape(K, C * G, D)
    qr = _pad_axis(qr, 1, 8)
    CGp = qr.shape[1]
    qp = _pad_last(qr.reshape(K * CGp, D), 128)
    kp = _pad_last(pool_k, 128)
    vp = _pad_last(pool_v, 128)
    nblk = jnp.sum(table >= 0)[None].astype(jnp.int32)
    o, m, l = paged_prefill_micro_attention_kernel(
        qp, kp, vp, table, nblk, tail_len[None], num_kv_heads=K,
        scale=scale, interpret=interpret)
    o = o.reshape(K, CGp, -1)[:, :C * G, :D]
    m = m.reshape(K, CGp)[:, :C * G]
    l = l.reshape(K, CGp)[:, :C * G]
    o = o.reshape(K, C, G, D).transpose(1, 0, 2, 3).reshape(C, H, D)
    m = m.reshape(K, C, G).transpose(1, 0, 2).reshape(C, H)
    l = l.reshape(K, C, G).transpose(1, 0, 2).reshape(C, H)
    return o, m, l


@functools.partial(jax.jit, static_argnames=("scale", "interpret", "backend"))
def paged_micro_attention(q, pool_k, pool_v, table, tail_len, *,
                          scale=None, interpret=None, backend=None):
    """Paged DistAttention MicroAttention partial (decode).

    q [R,H,D]; pool_k/v [NB,bs,K,D]; table [R,MB] (-1 padded, seq order);
    tail_len [R] valid tokens in each request's LAST local slot.
    ``backend``: "pallas" (kernel; interpret mode off-TPU) or "jnp" (pure
    gather fallback); None picks pallas on TPU and jnp elsewhere.
    Returns (o [R,H,D] f32 unnormalized, m [R,H] f32, l [R,H] f32).
    """
    R, H, D = q.shape
    if scale is None:
        scale = D ** -0.5
    if backend is None:
        backend = "pallas" if (_on_tpu() or interpret is not None) else "jnp"
    if backend == "jnp":
        return paged_micro_attention_jnp(q, pool_k, pool_v,
                                         table.astype(jnp.int32),
                                         tail_len.astype(jnp.int32),
                                         scale=scale)
    if interpret is None:
        interpret = not _on_tpu()
    nblk = jnp.sum(table >= 0, axis=1).astype(jnp.int32)
    qp = _pad_last(q, 128)
    kp = _pad_last(pool_k, 128)
    vp = _pad_last(pool_v, 128)
    o, m, l = paged_micro_attention_kernel(
        qp, kp, vp, table.astype(jnp.int32), nblk,
        tail_len.astype(jnp.int32), scale=scale, interpret=interpret)
    return o[:, :, :D], m, l


def paged_micro_attention_ranks(q, pools_k, pools_v, tables, tails, *,
                                scale=None, backend=None):
    """Decode MicroAttention partials over a stacked set of rank pools.

    q [R,H,D] broadcast to every rank; pools_k/v [NR,NB,bs,K,D] one pool
    slab per rank; tables [NR,R,MB]; tails [NR,R]. Returns stacked
    partials (o [NR,R,H,D], m [NR,R,H], l [NR,R,H]) — merge with
    ``merge_partials(axis=0)`` (vmap path) or compute per-shard inside
    shard_map and merge with ``merge_partials_collective``.
    """
    return jax.vmap(
        lambda pk, pv, tb, tl: paged_micro_attention(
            q, pk, pv, tb, tl, scale=scale, backend=backend)
    )(pools_k, pools_v, tables, tails)


def paged_prefill_attention_ranks(q, pools_k, pools_v, tables, tails, *,
                                  scale=None, backend=None):
    """Prefill-chunk MicroAttention partials over stacked rank pools.

    q [C,H,D] chunk queries broadcast to every rank; pools_k/v
    [NR,NB,bs,K,D]; tables [NR,MB]; tails [NR]. Returns stacked partials
    (o [NR,C,H,D], m [NR,C,H], l [NR,C,H]).
    """
    return jax.vmap(
        lambda pk, pv, tb, tl: paged_prefill_attention(
            q, pk, pv, tb, tl, scale=scale, backend=backend)
    )(pools_k, pools_v, tables, tails)
