"""Pallas TPU kernel: paged DistAttention MicroAttention (prefill chunk).

A whole chunk of C query rows (positions [t0, t0+C)) attends over this
rank's slice of the paged KV pool — the already-written prefix [0, t0)
addressed by ONE shared, scalar-prefetched block table. Because every
addressed token precedes every chunk query, no causal mask is needed
inside the kernel: validity is purely the table (-1 slots skipped) and
the tail length of the final block. The unnormalized partial
``(o, m, l)`` (paper Eq. 2) LSE-merges with the chunk-internal causal
partial and the other ranks' partials (paper Eq. 3), which is what makes
streaming paged prefill equal dense full-prefix attention.

TPU mapping:
  grid = (MB,): local-table slots, sequential, so the online-softmax
  accumulator for ALL C queries lives in VMEM scratch across slots.
  BlockSpec prefetches pool block ``table[j]`` straight from HBM into
  VMEM; blocks not in the table are never touched and -1 slots are
  skipped by ``pl.when``.
  The wrapper lays queries out as [K * C * G, D] (kv-head-major) so each
  kv-head group is a contiguous [C*G, D] row slab: (q @ k^T) is a
  [C*G, D] x [D, bs] MXU matmul per kv head, (p @ v) is [C*G, bs] x
  [bs, D]. fp32 accumulation throughout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(table_ref, nblk_ref, tail_ref,          # scalar prefetch (SMEM)
            q_ref, k_ref, v_ref,                    # VMEM inputs
            o_ref, m_ref, l_ref,                    # VMEM outputs
            acc, m_s, l_s,                          # VMEM scratch
            *, bs: int, K: int, CG: int, scale: float, mb: int):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    block_id = table_ref[j]

    @pl.when(block_id >= 0)
    def _compute():
        # Only the prefix's LAST block can be partially written.
        limit = jnp.where(j == nblk_ref[0] - 1, tail_ref[0], bs)
        valid = (jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
                 < limit)                                    # [1, bs]
        for kh in range(K):                                  # unrolled
            rows = slice(kh * CG, (kh + 1) * CG)
            qk = q_ref[rows, :].astype(jnp.float32)          # [CG, D]
            kb = k_ref[0, :, kh, :].astype(jnp.float32)      # [bs, D]
            vb = v_ref[0, :, kh, :].astype(jnp.float32)
            s = jax.lax.dot_general(
                qk, kb, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale  # [CG, bs]
            s = jnp.where(valid, s, NEG_INF)
            m_blk = jnp.max(s, axis=-1)                      # [CG]
            m_old = m_s[0, rows]
            m_new = jnp.maximum(m_old, m_blk)
            alpha = jnp.where(jnp.isneginf(m_old), 0.0,
                              jnp.exp(m_old - m_new))
            p = jnp.exp(s - jnp.where(jnp.isneginf(m_new), 0.0,
                                      m_new)[:, None])
            p = jnp.where(valid, p, 0.0)                     # [CG, bs]
            l_new = l_s[0, rows] * alpha + jnp.sum(p, -1)
            pv = jax.lax.dot_general(
                p, vb, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)          # [CG, D]
            acc[rows, :] = acc[rows, :] * alpha[:, None] + pv
            m_s[0, rows] = m_new
            l_s[0, rows] = l_new

    @pl.when(j == mb - 1)
    def _finalize():
        o_ref[...] = acc[...]
        m_ref[...] = m_s[...]
        l_ref[...] = l_s[...]


def paged_prefill_micro_attention_kernel(
    q: jax.Array,          # [K * CG, D] kv-head-major chunk queries
    pool_k: jax.Array,     # [NB, bs, K, D]
    pool_v: jax.Array,
    table: jax.Array,      # [MB] int32 (-1 padded, sequence order)
    nblk: jax.Array,       # [1] int32 valid slots of the shared table
    tail_len: jax.Array,   # [1] int32 valid tokens in the LAST slot
    *,
    num_kv_heads: int,
    scale: float,
    interpret: bool = True,
):
    KCG, D = q.shape
    NB, bs, K, _ = pool_k.shape
    assert K == num_kv_heads and KCG % K == 0
    CG = KCG // K
    MB = table.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(MB,),
        in_specs=[
            pl.BlockSpec((KCG, D), lambda j, t, n, tl: (0, 0)),
            pl.BlockSpec((1, bs, K, D),
                         lambda j, t, n, tl: (jnp.maximum(t[j], 0),
                                              0, 0, 0)),
            pl.BlockSpec((1, bs, K, D),
                         lambda j, t, n, tl: (jnp.maximum(t[j], 0),
                                              0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((KCG, D), lambda j, t, n, tl: (0, 0)),
            pl.BlockSpec((1, KCG), lambda j, t, n, tl: (0, 0)),
            pl.BlockSpec((1, KCG), lambda j, t, n, tl: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((KCG, D), jnp.float32),
            pltpu.VMEM((1, KCG), jnp.float32),
            pltpu.VMEM((1, KCG), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, bs=bs, K=K, CG=CG, scale=scale,
                               mb=MB)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((KCG, D), jnp.float32),
            jax.ShapeDtypeStruct((1, KCG), jnp.float32),
            jax.ShapeDtypeStruct((1, KCG), jnp.float32),
        ],
        interpret=interpret,
    )(table, nblk, tail_len, q, pool_k, pool_v)
