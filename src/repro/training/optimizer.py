"""AdamW (pure JAX), with optional bf16 moment storage for 1T-scale runs.

State layout mirrors the parameter pytree, so ZeRO-style sharding of
optimizer state falls out of the sharding rules (state shards like its
parameter, over the ``data`` axis when FSDP is on).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"      # "bfloat16" halves optimizer HBM


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_adamw(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    def zeros(p):
        return jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    step = state.step + 1
    lr = lr_schedule(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g * g * (1 - cfg.b2)
        update = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), \
        {"grad_norm": gnorm, "lr": lr}
