"""Deterministic synthetic data pipeline (seeded, stateless: step -> batch).

Restart-safe by construction: the batch for step N is a pure function of
(seed, step), so checkpoint/restart resumes the exact token stream with no
pipeline state to persist. Mimics a packed LM pipeline: documents of
Zipf-ish length packed into fixed-length rows with EOS separators.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_token: int = 0


def batch_for_step(cfg: DataConfig, step: int) -> Tuple[np.ndarray,
                                                        np.ndarray]:
    """Returns (tokens [B, S+1] int32, loss_mask [B, S] float32).

    tokens[:, :-1] are inputs, tokens[:, 1:] targets; mask zeroes the
    positions crossing document boundaries.
    """
    rng = np.random.default_rng((cfg.seed << 20) ^ step)
    B, S = cfg.global_batch, cfg.seq_len
    toks = rng.integers(1, cfg.vocab_size, size=(B, S + 1), dtype=np.int64)
    mask = np.ones((B, S), np.float32)
    # Pack documents: draw boundaries with Zipf-like lengths.
    for b in range(B):
        pos = 0
        while pos < S:
            ln = int(min(S - pos, max(8, rng.pareto(1.2) * 64)))
            pos += ln
            if pos < S:
                toks[b, pos] = cfg.eos_token
                mask[b, pos] = 0.0          # don't predict across docs
                pos += 1
    return toks.astype(np.int32), mask


def data_iterator(cfg: DataConfig, start_step: int = 0
                  ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    step = start_step
    while True:
        yield batch_for_step(cfg, step)
        step += 1
