"""Train step: masked LM loss, remat, microbatch accumulation, AdamW.

The step is GSPMD-friendly (pure global-view jnp; sharding comes from the
in/out shardings set by the launcher). Gradient int8-compression with
error feedback is applied numerically before the update (the wire-level
pod-axis variant lives in ``repro.training.grad_sync`` and is exercised
by the multi-pod lowering).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import forward
from repro.training.compression import (compress_grads_with_ef,
                                        decompress_grads,
                                        init_error_feedback)
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                      init_adamw)


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: bool = True
    moe_aux_weight: float = 0.01
    capacity_factor: float = 1.25
    grad_compression: bool = False
    attn_chunk: int = 512
    moe_ep_groups: int = 0   # >1: 2D EP dispatch (see repro.models.moe)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Any                  # error-feedback buffers (or None)


def init_train_state(params, adam_cfg: AdamWConfig, tcfg: TrainConfig
                     ) -> TrainState:
    ef = init_error_feedback(params) if tcfg.grad_compression else None
    return TrainState(params, init_adamw(params, adam_cfg), ef)


def lm_loss(params, cfg: ModelConfig, tokens, mask, tcfg: TrainConfig,
            embeds=None, layer_constraints=None):
    """tokens [B, S+1]; mask [B, S]. Returns (loss, metrics)."""
    inp = tokens[:, :-1]
    tgt = tokens[:, 1:]
    logits, aux = forward(params, cfg, inp, embeds, backend="xla",
                          chunk=tcfg.attn_chunk, remat=tcfg.remat,
                          capacity_factor=tcfg.capacity_factor,
                          ep_groups=tcfg.moe_ep_groups,
                          layer_constraints=layer_constraints)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    tgt_logit = jnp.take_along_axis(
        logits.astype(jnp.float32), tgt[..., None], axis=-1)[..., 0]
    nll = (lse - tgt_logit) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = nll.sum() / denom
    loss = ce + tcfg.moe_aux_weight * aux
    return loss, {"ce": ce, "aux": aux,
                  "tokens": denom}


def train_step(state: TrainState, tokens, mask, *, cfg: ModelConfig,
               tcfg: TrainConfig, adam_cfg: AdamWConfig,
               embeds=None, layer_constraints=None
               ) -> Tuple[TrainState, dict]:
    """One optimizer step (optionally accumulated over microbatches)."""
    grad_fn = jax.value_and_grad(
        lambda p, t, m, e: lm_loss(p, cfg, t, m, tcfg, e,
                                   layer_constraints), has_aux=True)

    if tcfg.microbatches <= 1:
        (loss, metrics), grads = grad_fn(state.params, tokens, mask, embeds)
    else:
        n = tcfg.microbatches
        B = tokens.shape[0]
        assert B % n == 0, "global batch must divide microbatches"
        tks = tokens.reshape(n, B // n, *tokens.shape[1:])
        mks = mask.reshape(n, B // n, *mask.shape[1:])
        embs = (None if embeds is None
                else embeds.reshape(n, B // n, *embeds.shape[1:]))

        def acc_body(carry, xs):
            g_acc, l_acc = carry
            if embs is None:
                tk, mk = xs
                (l, _), g = grad_fn(state.params, tk, mk, None)
            else:
                tk, mk, eb = xs
                (l, _), g = grad_fn(state.params, tk, mk, eb)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (g_acc, l_acc + l), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             state.params)
        xs = (tks, mks) if embs is None else (tks, mks, embs)
        (grads, loss_sum), _ = jax.lax.scan(acc_body, (zeros, 0.0), xs)
        grads = jax.tree.map(lambda g: g / n, grads)
        loss = loss_sum / n

    ef = state.ef
    if tcfg.grad_compression:
        qgrads, ef = compress_grads_with_ef(grads, ef)
        grads = decompress_grads(qgrads)

    params, opt, opt_metrics = adamw_update(state.params, grads, state.opt,
                                            adam_cfg)
    out = {"loss": loss, **opt_metrics}
    return TrainState(params, opt, ef), out
