"""int8 gradient compression with error feedback (distributed-opt trick).

Per-tensor symmetric quantization: g ~ scale * int8. The quantization
residual is carried in an error-feedback buffer and added back next step,
so compression introduces no bias in the long run (EF-SGD style). Used on
the data/pod-axis gradient all-reduce to cut cross-pod DCN traffic 4x
versus fp32 (2x vs bf16); enable with TrainConfig.grad_compression.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads_with_ef(grads, ef):
    """Returns (quantized pytree of (q, scale), new_ef).

    new_ef holds the quantization residual, re-injected next step.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return (q, s), corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qtree = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    new_ef = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return qtree, new_ef


def decompress_grads(qtree):
    """Inverse of compress (after the int8 all-reduce/all-gather)."""
    return jax.tree.map(lambda qs: dequantize_int8(*qs), qtree,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and not isinstance(x[0], tuple))
