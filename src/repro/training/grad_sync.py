"""Wire-level compressed gradient sync across the pod (DCN) axis.

``grad_sync_compressed`` is a shard_map body: each pod holds its local
gradient; we quantize to int8 (+ fp32 scale), all_gather over the ``pod``
axis, and average after dequantization. DCN bytes drop 4x vs fp32 (2x vs
bf16); the int8 all-gather is visible in lowered HLO, which the multi-pod
dry-run and §Perf use to account the savings.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.training.compression import quantize_int8


def _sync_one(g, axis_name):
    q, s = quantize_int8(g)
    qs = jax.lax.all_gather(q, axis_name)            # [n_pods, ...] int8
    ss = jax.lax.all_gather(s, axis_name)            # [n_pods] f32
    n = qs.shape[0]
    deq = qs.astype(jnp.float32) * ss.reshape((n,) + (1,) * g.ndim)
    return jnp.mean(deq, axis=0).astype(g.dtype)


def grad_sync_compressed(grads, axis_name: str = "pod"):
    """shard_map body: int8 all-gather + local mean over ``axis_name``."""
    return jax.tree.map(lambda g: _sync_one(g, axis_name), grads)


def make_grad_sync(mesh, axis_name: str = "pod"):
    """jit-able compressed cross-pod gradient averaging.

    Gradients are assumed replicated within a pod (post data-axis psum)
    and DIFFERENT across pods; output is the pod-averaged gradient.
    """
    from jax.experimental.shard_map import shard_map

    def spec_for(g):
        return P(axis_name, *([None] * (g.ndim)))    # stacked per pod

    def sync(stacked_grads):
        # stacked_grads: each leaf [n_pods, ...]; shard over pod axis.
        in_specs = jax.tree.map(lambda g: P(axis_name), stacked_grads)
        out_specs = in_specs

        def body(gl):
            return jax.tree.map(
                lambda g: _sync_one(g[0], axis_name)[None], gl)

        return shard_map(body, mesh=mesh, in_specs=(in_specs,),
                         out_specs=out_specs)(stacked_grads)

    return jax.jit(sync)
