"""The paper's performance model (Eq. 5-7), calibrated for TPU v5e.

  T_layer(beta, S) = T_natn(beta) + T_atn(S)
                   = W(beta) / f(beta) + sum_r S_r / g(S)        (Eq. 5)

* W(beta): non-attention FLOPs per layer for a decode step of batch beta —
  2 FLOPs per active parameter per token.
* f(beta): achieved FLOP/s. Non-attention GEMMs at decode are bandwidth
  bound until the batch reaches the critical arithmetic intensity
  (~240 on v5e): f(beta) = peak * min(1, beta / I_crit). This reproduces
  the paper's Fig. 2(c) saturation shape.
* g(S): attention "performance". Decode attention is strictly bandwidth
  bound (each KV byte read once, intensity ~1 FLOP/byte), so we express
  T_atn directly as KV bytes / HBM bandwidth; g(S) is constant in S —
  matching the paper's observation that attention does not batch.

Debtor/creditor adjustments (Eq. 6) subtract/add the offloaded KV-bytes
time; cluster throughput is the sum of instance TPS (Eq. 7).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

from repro.configs.base import ModelConfig
from repro.distributed.hardware import V5E, HardwareSpec


@dataclass
class InstancePerfModel:
    """Paper Eq. 5-7 analytic step-time model for one instance.

    Decomposes a decode step into non-attention compute (Eq. 5),
    bandwidth-bound attention over resident KV, TP collectives, and the
    debtor/creditor corrections (Eq. 6-7); the scheduler and SLO victim
    ranking consume ``predicted_finish_s``/``t_preempt_roundtrip``.
    """

    cfg: ModelConfig
    hw: HardwareSpec = V5E
    chips: int = 1                 # chips per instance (TP degree)
    bytes_per_el: int = 2

    # ------------------------------------------------------------------ #
    def _active_params_per_layer(self) -> float:
        c = self.cfg
        body = c.active_param_count() - c.vocab_size * c.d_model * \
            (1 if c.tie_embeddings else 2)
        return body / max(1, c.num_layers)

    def w_natn(self, beta: int) -> float:
        """Non-attention FLOPs for one decode step of one layer (Eq. 5 W)."""
        return 2.0 * beta * self._active_params_per_layer()

    def f_natn(self, beta: int) -> float:
        """Achieved non-attention FLOP/s at batch beta (saturating ramp)."""
        peak = self.hw.peak_flops_bf16 * self.chips
        return peak * min(1.0, beta / self.hw.critical_intensity)

    def t_natn(self, beta: int) -> float:
        """Non-attention time of one layer at batch ``beta`` (Eq. 5)."""
        if beta <= 0:
            return 0.0
        return self.w_natn(beta) / self.f_natn(beta)

    def kv_bytes_per_token_layer(self) -> float:
        """KV bytes one token adds per layer (both K and V)."""
        c = self.cfg
        return 2.0 * c.num_kv_heads * c.head_dim * self.bytes_per_el

    def t_atn(self, lengths: Sequence[int]) -> float:
        """Attention time of one layer: sum_r S_r / g (bandwidth bound)."""
        kv_bytes = sum(lengths) * self.kv_bytes_per_token_layer()
        return kv_bytes / (self.hw.hbm_bw * self.chips)

    # Per-hop collective latency on the ICI ring (~1 us on v5e).
    alpha_hop: float = 1e-6

    def t_tp_comm(self, beta: int) -> float:
        """Per-layer TP collective time: two all-reduces (attention out +
        FFN out) of [beta, d_model] activations over the ring, bandwidth
        PLUS per-hop latency 2(c-1)*alpha each — the latency term is what
        makes wide TP inefficient at decode (paper Fig. 1(c) / Obs. 1:
        over-segmentation of the non-attention layers)."""
        if self.chips <= 1:
            return 0.0
        bytes_ar = 2 * 2 * beta * self.cfg.d_model * self.bytes_per_el \
            * (self.chips - 1) / self.chips
        latency = 2 * 2 * (self.chips - 1) * self.alpha_hop
        return bytes_ar / self.hw.ici_link_bw + latency

    def t_layer(self, beta: int, lengths: Sequence[int]) -> float:
        """Undisturbed per-layer step time (Eq. 5 both terms + TP)."""
        return self.t_natn(beta) + self.t_atn(lengths) \
            + self.t_tp_comm(beta)

    # --- Eq. 6: debtor / creditor corrections ------------------------- #
    def t_layer_debtor(self, beta: int, lengths: Sequence[int],
                       offloaded_tokens: int) -> float:
        """Debtor: ``offloaded_tokens`` of its KV live on creditors."""
        off_bytes = offloaded_tokens * self.kv_bytes_per_token_layer()
        return self.t_layer(beta, lengths) - off_bytes / \
            (self.hw.hbm_bw * self.chips)

    def t_layer_creditor(self, beta: int, lengths: Sequence[int],
                         hosted_tokens: int) -> float:
        """Creditor: computes MicroAttention for ``hosted_tokens`` of
        others' KV."""
        host_bytes = hosted_tokens * self.kv_bytes_per_token_layer()
        return self.t_layer(beta, lengths) + host_bytes / \
            (self.hw.hbm_bw * self.chips)

    # --- striped-span merge traffic (per (request, creditor) entry) --- #
    def merge_bytes_per_span_layer(self) -> float:
        """Per-step, per-layer bytes exchanged for ONE (request, creditor)
        span entry: the shipped query q plus the returned MicroAttention
        partial (o, m, l) — exactly what ``CommStats.query_shipped``
        counts on the real engine. Every extra stripe of a request adds
        one more of these exchanges per step."""
        c = self.cfg
        q = c.num_heads * c.head_dim * self.bytes_per_el
        o = c.num_heads * c.head_dim * 4          # f32 partial output
        ml = 2 * c.num_heads * 4                  # f32 max + log-sum-exp
        return q + o + ml

    def t_span_merge(self, span_entries: int) -> float:
        """Per-layer time spent on striped-span query/merge traffic.

        Each entry pays its bytes over the inter-instance link plus a
        per-message hop latency — the term that makes striping a request
        across many creditors a modeled cost, not a free lunch."""
        if span_entries <= 0:
            return 0.0
        b = span_entries * self.merge_bytes_per_span_layer()
        return b / self.hw.ici_link_bw + span_entries * self.alpha_hop

    # --- host-tier (DRAM) transfer time -------------------------------- #
    def t_host_transfer(self, n_tokens: int) -> float:
        """Time for ``n_tokens`` of KV to cross the device<->host link —
        a spill (D2H) or prefetch (H2D) of that many cached tokens. The
        runtime overlaps these with decode; the scheduler still charges
        them un-overlapped as the conservative spill penalty when a plan
        displaces cached blocks (mirrors ``_reclaim_pays``)."""
        kv_bytes = n_tokens * self.kv_bytes_per_token_layer() \
            * self.cfg.num_layers
        return kv_bytes / (self.hw.host_link_bw * self.chips)

    def t_preempt_roundtrip(self, n_tokens: int) -> float:
        """Modeled cost of pausing+resuming a request with ``n_tokens``
        of resident KV: one D2H spill plus one H2D prefetch over the
        host link (2x ``t_host_transfer``). The SLO-aware victim picker
        charges this against a victim's slack so preemption is never
        modeled as free."""
        return 2.0 * self.t_host_transfer(n_tokens)

    def predicted_finish_s(self, beta: int, lengths: Sequence[int],
                           remaining_tokens: int,
                           offloaded_tokens: int = 0,
                           hosted_tokens: int = 0,
                           span_entries: int = 0) -> float:
        """Seconds until a request with ``remaining_tokens`` left to
        decode finishes on an instance in the given state (Eq. 5-7).

        Each decode step emits one token per running request, so the
        per-request token rate is ``tps / beta``; the finish horizon is
        remaining_tokens / that rate. Used for SLO slack
        (slack = deadline - now - predicted_finish) in victim selection
        and dispatch ordering."""
        if remaining_tokens <= 0:
            return 0.0
        rate = self.tps(max(1, beta), lengths, offloaded_tokens,
                        hosted_tokens, span_entries) / max(1, beta)
        return remaining_tokens / max(rate, 1e-9)

    # --- Eq. 7: instance / cluster throughput ------------------------- #
    def tps(self, beta: int, lengths: Sequence[int],
            offloaded_tokens: int = 0, hosted_tokens: int = 0,
            span_entries: int = 0, max_span_tokens: int = 0) -> float:
        """Decode tokens/second of the instance.

        Beyond the paper's Eq. 6 we enforce its §5.2.1 coverage
        constraint: the debtor cannot finish a step before the remote
        MicroAttention it depends on — its effective layer time is
        max(local time after offload, remote MA time). Without this the
        model claims unbounded gain from offloading everything.

        ``span_entries`` counts this instance's (request, creditor) span
        pairs: each pays per-step query/merge traffic (t_span_merge).
        ``max_span_tokens`` (optional) is the largest single-creditor
        slice of this instance's offloaded KV: remote MicroAttention
        runs in PARALLEL across creditors, so the remote bound is the
        slowest slice, not the total — striping over more creditors
        shrinks it (at the cost of more span entries). When 0, the
        single-creditor worst case (all offloaded on one rank) is
        assumed.
        """
        if beta <= 0:
            return 0.0
        per_tok_t = self.kv_bytes_per_token_layer() / \
            (self.hw.hbm_bw * self.chips)
        off_t = offloaded_tokens * per_tok_t
        slice_tokens = max_span_tokens if max_span_tokens > 0 \
            else offloaded_tokens
        t_local = self.t_layer(beta, lengths) - off_t
        t = max(t_local, slice_tokens * per_tok_t)  # Fig. 6(a) coverage
        t += hosted_tokens * per_tok_t
        t += self.t_span_merge(span_entries)
        t = max(t, 1e-12)
        return beta / (self.cfg.num_layers * t)

    # --- memory ------------------------------------------------------- #
    def kv_tokens_capacity(self, reserve_frac: float = 0.1) -> int:
        """How many KV tokens fit on this instance beside the weights."""
        c = self.cfg
        weight_bytes = c.param_count() * self.bytes_per_el
        total = self.hw.hbm_bytes * self.chips * (1 - reserve_frac)
        avail = max(0.0, total - weight_bytes)
        per_tok = c.kv_bytes_per_token(self.bytes_per_el)
        return int(avail / per_tok) if per_tok else 1 << 60


def cluster_tps(models: List[InstancePerfModel], betas: List[int],
                lengths: List[List[int]], offloaded: List[int],
                hosted: List[int]) -> float:
    """Eq. 7: aggregated cluster throughput."""
    return sum(m.tps(b, ls, off, host) for m, b, ls, off, host
               in zip(models, betas, lengths, offloaded, hosted))
