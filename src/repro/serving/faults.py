"""Deterministic fault injection + the recovery vocabulary it exercises.

The paper's premise — one request's KV scattered across a pooled
cluster — means a single failed creditor rank, dropped move leg, or
corrupted host frame can silently destroy OTHER instances' requests.
This module is the chaos side of the fault-tolerance machinery: a
seedable, step-addressed ``FaultPlan`` whose events the ``Cluster``
fires inside its own ``step()`` loop, so every failure mode the
recovery paths claim to survive can be reproduced exactly:

* ``crash``          — an instance stops heartbeating (``kill_instance``);
  the gManager detects it after ``FaultPolicy.heartbeat_timeout_steps``
  missed beats and the cluster replays every affected request.
* ``silence``        — heartbeats suppressed for ``duration`` steps: a
  gap SHORTER than the timeout must be tolerated (no recovery), a
  longer one must be treated exactly like a crash.
* ``move_leg``       — the next executed stripe leg fails mid-plan: the
  remaining legs' reservations roll back exactly and the tail re-plans
  against surviving creditors.
* ``host_fetch``     — the next host-tier ``get`` raises a (transient)
  ``TransferError``; bounded exponential-backoff retries absorb it.
* ``host_corrupt``   — the next fetched host frame is bit-flipped; hash
  verification raises ``FrameCorruptionError`` instead of letting the
  poisoned KV reach decode, and the caller falls back to token replay.
* ``stager_timeout`` — the next drained stager chain raises a
  ``TransferError`` (retried within the stager's budget).

Everything is deterministic: ``FaultPlan.from_seed`` derives the event
list from a PRNG seed, events fire at exact cluster step counts, and
transfer faults are one-shot armed flags consumed in execution order —
the hypothesis property suite in ``tests/test_faults.py`` leans on
this to assert the allocator never leaks under ARBITRARY plans.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

#: Every fault kind a plan may carry, in the order ``from_seed`` draws.
FAULT_KINDS = ("crash", "silence", "move_leg", "host_fetch",
               "host_corrupt", "stager_timeout")


class TransferError(RuntimeError):
    """A KV transfer (stager chain drain, host-tier fetch) failed.

    Transient by contract: callers retry within their
    ``FaultPolicy``-bounded backoff budget before propagating.
    """


class FrameCorruptionError(RuntimeError):
    """A host-tier frame failed verification against the content hash
    it was stored under — NOT retryable (the stored bytes are wrong);
    the caller must fall back to token-replay recovery."""


def backoff_delay_s(attempt: int, base_s: float, cap_s: float) -> float:
    """Bounded exponential backoff: ``min(cap, base * 2**attempt)``.

    ``base_s == 0`` (the smoke/test default) means immediate in-process
    retries — the retry COUNTING still happens, only the sleeping is
    skipped."""
    if base_s <= 0.0:
        return 0.0
    return min(cap_s, base_s * (2.0 ** attempt))


@dataclass(frozen=True)
class FaultEvent:
    """One planned fault: ``kind`` fires at cluster step ``step``.

    ``target`` picks the instance for crash/silence events (-1 = let
    the injector pick deterministically among the live ones);
    ``duration`` is the silenced-step count for ``silence``; ``count``
    arms that many one-shot transfer faults for the hook-consumed
    kinds (move_leg / host_fetch / host_corrupt / stager_timeout).
    """

    step: int
    kind: str
    target: int = -1
    duration: int = 1
    count: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {FAULT_KINDS})")
        if self.step < 1:
            raise ValueError("fault events fire at step >= 1")
        if self.duration < 1 or self.count < 1:
            raise ValueError("duration/count must be >= 1")


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic, seedable schedule of ``FaultEvent``s."""

    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0

    @classmethod
    def from_seed(cls, seed: int, *, n_steps: int, n_instances: int,
                  n_events: int = 3, kinds: Tuple[str, ...] = FAULT_KINDS,
                  max_crashes: int = 1) -> "FaultPlan":
        """Derive a plan from ``seed`` alone: the same seed always
        yields the same events (steps in [1, n_steps], targets in
        [0, n_instances)). At most ``max_crashes`` crash events are
        drawn — a crash beyond the budget degrades to a transfer fault
        so arbitrary seeds can never kill the whole cluster."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        crashes = 0
        for _ in range(n_events):
            kind = kinds[int(rng.integers(len(kinds)))]
            if kind == "crash":
                if crashes >= max_crashes or n_instances < 2:
                    kind = "stager_timeout"
                else:
                    crashes += 1
            events.append(FaultEvent(
                step=int(rng.integers(1, max(2, n_steps + 1))),
                kind=kind,
                target=int(rng.integers(n_instances)),
                duration=int(rng.integers(1, 5))))
        events.sort(key=lambda e: (e.step, e.kind, e.target))
        return cls(events=tuple(events), seed=seed)


@dataclass
class FaultStats:
    """Cluster-side counters of detection, recovery, and retry work."""

    dead_instances: int = 0      # ranks quarantined by detection
    recoveries: int = 0          # requests re-admitted via token replay
    failed_recoveries: int = 0   # replay budget exhausted -> FAILED
    replayed_tokens: int = 0     # generated tokens re-prefilled
    move_leg_failures: int = 0   # stripe legs that failed mid-execution
    move_leg_replans: int = 0    # failed tails re-planned successfully
    injected: int = 0            # plan events actually fired


class FaultInjector:
    """Fires a ``FaultPlan`` against a live cluster, deterministically.

    ``attach(cluster)`` installs the hooks (stager + host tiers) and
    registers the injector on the cluster; the cluster then calls
    ``on_step`` at the top of every ``step()``. Crash/silence events
    act immediately; transfer faults are ARMED one-shot flags the
    subsystem hooks consume in execution order, so a fault planned at
    step k hits the first matching transfer at-or-after step k."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._by_step: Dict[int, List[FaultEvent]] = {}
        for ev in plan.events:
            self._by_step.setdefault(ev.step, []).append(ev)
        self._silent_until: Dict[int, int] = {}   # inst -> last silent step
        self._move_leg_armed = 0
        self._host_armed: List[str] = []          # "error" | "corrupt" queue
        self._stager_armed = 0
        self.fired: List[FaultEvent] = []

    # --- wiring -------------------------------------------------------- #
    def attach(self, cluster) -> "FaultInjector":
        """Install this injector's hooks on ``cluster`` and return it."""
        cluster.faults = self
        cluster.stager.fault_hook = self.stager_fault
        if cluster.host_tier is not None:
            cluster.host_tier.fault_hook = self.host_fault
        if cluster.preemptor is not None:
            cluster.preemptor.tier.fault_hook = self.host_fault
        return self

    # --- event firing --------------------------------------------------- #
    def on_step(self, step: int, cluster) -> None:
        """Fire every event planned for cluster step ``step``."""
        for ev in self._by_step.get(step, ()):
            self._fire(ev, step, cluster)

    def _pick_target(self, ev: FaultEvent, cluster) -> Optional[int]:
        live = sorted(i for i in cluster.engines if i not in cluster._dead)
        if len(live) < 2:
            return None          # never take the last live instance down
        if ev.target in live:
            return ev.target
        return live[max(ev.target, 0) % len(live)]

    def _fire(self, ev: FaultEvent, step: int, cluster) -> None:
        if ev.kind == "crash":
            target = self._pick_target(ev, cluster)
            if target is None:
                return           # skipped: would strand the cluster
            cluster.kill_instance(target)
        elif ev.kind == "silence":
            target = self._pick_target(ev, cluster)
            if target is None:
                return
            self._silent_until[target] = max(
                self._silent_until.get(target, 0),
                step + ev.duration - 1)
        elif ev.kind == "move_leg":
            self._move_leg_armed += ev.count
        elif ev.kind == "host_fetch":
            self._host_armed.extend(["error"] * ev.count)
        elif ev.kind == "host_corrupt":
            self._host_armed.extend(["corrupt"] * ev.count)
        elif ev.kind == "stager_timeout":
            self._stager_armed += ev.count
        cluster.fault_stats.injected += 1
        self.fired.append(ev)

    # --- hooks consumed by the subsystems ------------------------------- #
    def silenced(self, inst_id: int, step: int) -> bool:
        """True while ``inst_id``'s heartbeat is suppressed at ``step``."""
        return step <= self._silent_until.get(inst_id, 0)

    def take_move_leg_fault(self) -> bool:
        """Consume one armed move-leg fault (False when none armed)."""
        if self._move_leg_armed > 0:
            self._move_leg_armed -= 1
            return True
        return False

    def host_fault(self, key) -> Optional[str]:
        """Consume one armed host-tier fault: "error" (transient fetch
        failure), "corrupt" (bit-flip the stored frame), or None."""
        if self._host_armed:
            return self._host_armed.pop(0)
        return None

    def stager_fault(self, tag: Optional[str]) -> bool:
        """Consume one armed stager transfer fault (False when none)."""
        if self._stager_armed > 0:
            self._stager_armed -= 1
            return True
        return False


__all__ = ["FAULT_KINDS", "FaultEvent", "FaultInjector", "FaultPlan",
           "FaultStats", "FrameCorruptionError", "TransferError",
           "backoff_delay_s"]
