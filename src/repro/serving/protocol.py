"""The paper's §6 protocol objects (Listing 1) + message structs.

heartbeat:            rManager -> gManager, delta-encoded placement entries
move_kvcache:         gManager -> rManager (src), a planned movement
try_move_kvcache:     src rManager -> dst rManager, FCFS space reservation
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass(frozen=True)
class RequestPlacementEntry:
    """One request's KVCache footprint on one instance (paper Listing 1)."""
    req_id: int
    inst_id: int
    num_blocks: int
    local: bool            # True if this instance is the request's debtor
                           # (owner) instance


@dataclass
class Heartbeat:
    inst_id: int
    seq: int                                   # monotone per instance
    full: bool                                 # full resync vs delta
    entries: List[RequestPlacementEntry]
    batch_size: int = 0
    mem_blocks_total: int = 0
    mem_blocks_used: int = 0
    removed_req_ids: List[int] = field(default_factory=list)


@dataclass
class MoveKVCache:
    """gManager instruction: move num_blocks of req_id src -> dst."""
    req_id: int
    num_blocks: int
    src_inst: int
    dst_inst: int


class MoveResult(enum.Enum):
    OK = "ok"
    REJECTED = "rejected"          # dst out of space (stale global view)
    GONE = "gone"                  # request finished/failed meanwhile
