"""The paper's §6 protocol objects (Listing 1) + message structs.

heartbeat:            rManager -> gManager, delta-encoded placement entries
move_kvcache:         gManager -> rManager (src), a planned movement
try_move_kvcache:     src rManager -> dst rManager, FCFS space reservation
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List


@dataclass(frozen=True)
class RequestPlacementEntry:
    """One request's KVCache footprint on one instance (paper Listing 1)."""
    req_id: int
    inst_id: int
    num_blocks: int
    local: bool            # True if this instance is the request's debtor
                           # (owner) instance


@dataclass
class Heartbeat:
    """Periodic rManager -> gManager state report (delta or full).

    Carries this instance's placement entries, batch size, and memory
    occupancy — the inputs Algorithm 1 plans from.
    """

    inst_id: int
    seq: int                                   # monotone per instance
    full: bool                                 # full resync vs delta
    entries: List[RequestPlacementEntry]
    batch_size: int = 0
    mem_blocks_total: int = 0
    mem_blocks_used: int = 0
    removed_req_ids: List[int] = field(default_factory=list)
    # Unpinned prefix-cache replicas: used blocks the instance can
    # reclaim on demand (evict/spill). Algorithm 1 counts them as
    # creditor capacity — minus a spill-cost penalty.
    cache_blocks: int = 0


@dataclass(frozen=True)
class MoveLeg:
    """One stripe of a movement plan: whole blocks onto one instance."""
    dst_inst: int
    num_blocks: int


@dataclass
class MoveKVCache:
    """gManager instruction: move req_id's oldest blocks from src_inst
    onto one or MORE destinations (a striped span plan).

    The runtime must execute the legs all-or-nothing: every destination
    is reserved (try_move_kvcache, FCFS) before any KV byte moves; if
    any leg is refused every reservation is cancelled and the plan is
    REJECTED — a stale global view can waste a plan, never corrupt
    state. ``kind`` is "offload" (debtor -> creditors) or "reclaim"
    (a stressed creditor evicts a hosted span back to its owner or
    sideways to other creditors).
    """
    req_id: int
    src_inst: int
    legs: List[MoveLeg]
    kind: str = "offload"

    @property
    def num_blocks(self) -> int:
        """Total blocks moved across all legs."""
        return sum(leg.num_blocks for leg in self.legs)


class MoveResult(enum.Enum):
    """Outcome of executing one ``MoveKVCache`` plan."""

    OK = "ok"
    REJECTED = "rejected"          # dst out of space (stale global view)
    # Request reached a terminal state (finished / failed / CANCELLED)
    # between planning and execution: the plan is invalidated before any
    # reservation is made, so a cancel racing a striped move can never
    # leave orphan reservations.
    GONE = "gone"
