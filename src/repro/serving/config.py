"""Typed serving configuration: every cluster/engine/frontend knob in
one frozen dataclass.

Before this existed the same dozen kwargs were threaded (with drifting
values) through ``Cluster.__init__``, ``GManager``, ``InstanceEngine``,
every example, the launcher, and every benchmark. ``ServingConfig`` is
now the single source of truth: ``Cluster(params, cfg, config=...)`` and
``LLMServer(params, cfg, config=...)`` take it, and the presets below
pin the two configurations the repo actually runs —

  * ``ServingConfig.smoke()``  — CPU smoke scale (tests, examples, CI
    benchmarks): tiny pools, 8-token blocks, chunked prefill small
    enough that every code path (spill, striping, reclaim) triggers on
    40-token prompts.
  * ``ServingConfig.v5e()``    — the paper-regime deployment shape the
    perf model is calibrated for (TPU v5e instance, 16-token blocks,
    production batch).

Both presets accept overrides: ``ServingConfig.smoke(n_instances=3)``.
Use ``cfg.replace(async_movement=False)`` to derive variants for A/Bs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class OverloadPolicy:
    """Overload-survival knobs: preemptive pause/host-spill scheduling.

    Off by default (``enabled=False``) the server behaves exactly as
    before: admission queues or rejects, running requests are never
    disturbed. Enabled, the frontend may PAUSE running requests at a
    step boundary — spilling their KV chain byte-for-byte to a
    dedicated pinned host-DRAM tier — to free slots/blocks for
    deadline-urgent arrivals, and resumes them later with identical
    tokens. Victim choice is SLO-aware: slack = deadline - predicted
    finish (perf model), charged the spill+resume round-trip via
    ``t_host_transfer``. Frozen like ``ServingConfig``; derive variants
    with ``dataclasses.replace``.
    """

    enabled: bool = False          # master switch for preemption
    preempt_host_blocks: int = 512  # host frames reserved for paused KV
    max_preemptions: int = 2       # per-request pause cap (anti-thrash)
    min_pause_s: float = 0.0       # min parked time before resume
    victim_min_slack_s: float = 0.5  # victim must keep this much slack
    #                                 AFTER paying the spill+resume cost
    arrival_alpha: float = 0.3     # EWMA weight of the arrival estimator

    def __post_init__(self):
        if not 0.0 < self.arrival_alpha <= 1.0:
            raise ValueError("arrival_alpha must be in (0, 1]")
        if self.enabled and self.preempt_host_blocks <= 0:
            raise ValueError(
                "preemption requires preempt_host_blocks > 0 (paused KV "
                "lives in the dedicated host tier)")
        if self.max_preemptions < 0 or self.min_pause_s < 0:
            raise ValueError("max_preemptions/min_pause_s must be >= 0")


@dataclass(frozen=True)
class FaultPolicy:
    """Fault-tolerance knobs: detection, retry budgets, replay caps.

    Always on — these bound how the cluster reacts when something
    breaks, they never cause work by themselves. Detection: an instance
    is marked DEAD and quarantined after ``heartbeat_timeout_steps``
    consecutive missed heartbeats (step-count based, deterministic —
    the wall-clock ``ServingConfig.heartbeat_timeout`` still applies
    independently). Recovery: every request that lost KV on a dead rank
    is re-admitted via token-replay re-prefill of ``prompt +
    output[:-1]`` (known tokens, no resampling), at most
    ``max_replays_per_request`` times before it FAILs. Transfers
    (stager drains, host-tier fetches) retry up to
    ``max_transfer_retries`` with bounded exponential backoff, and
    host frames are verified against the content hash they were stored
    under when ``verify_host_frames`` is set. Frozen like
    ``ServingConfig``; derive variants with ``dataclasses.replace``.
    """

    heartbeat_timeout_steps: int = 3   # missed beats before DEAD (0 = off)
    max_transfer_retries: int = 2      # per-transfer retry budget
    retry_backoff_base_s: float = 0.0  # backoff = min(cap, base * 2**i);
    retry_backoff_max_s: float = 0.05  # base 0 = immediate retries (tests)
    max_replays_per_request: int = 3   # replay recoveries before FAILED
    verify_host_frames: bool = True    # hash-check H2D host-tier fetches

    def __post_init__(self):
        if self.heartbeat_timeout_steps < 0:
            raise ValueError("heartbeat_timeout_steps must be >= 0")
        if self.max_transfer_retries < 0:
            raise ValueError("max_transfer_retries must be >= 0")
        if self.retry_backoff_base_s < 0 or self.retry_backoff_max_s < 0:
            raise ValueError("retry backoff times must be >= 0")
        if self.max_replays_per_request < 0:
            raise ValueError("max_replays_per_request must be >= 0")


@dataclass(frozen=True)
class ServingConfig:
    """All serving knobs. Frozen: derive variants via ``replace()``."""

    # --- cluster shape ------------------------------------------------ #
    n_instances: int = 2           # model replicas (paper: instances)
    max_batch: int = 8             # decode slots per instance
    global_pool: bool = False      # fold per-instance pools into ONE
    #                                mesh-shardable [ranks, L, NB, bs,
    #                                K, hd] tensor (GlobalKVPool); moves
    #                                and creditor reads become slice
    #                                assignments / shard_map partials
    # --- per-instance KV pool ----------------------------------------- #
    max_local_len: int = 128       # per-request LOCAL quota (tokens)
    pool_blocks: int = 64          # blocks in each instance's pool
    block_size: int = 16           # tokens per block
    prefill_chunk: int = 32        # streaming-admission chunk (tokens)
    # --- KV movement -------------------------------------------------- #
    move_chunk_tokens: int = 16    # reactive spill granularity
    async_movement: bool = True    # overlap pool-row copies with compute
    # --- prefix cache / host-DRAM tier -------------------------------- #
    prefix_cache: bool = False     # cross-request radix prefix caching
    host_tier_blocks: int = 0      # host-DRAM KV frames (0 = no tier;
    #                                requires prefix_cache — the cache
    #                                is the index into the tier)
    host_high_watermark: float = 0.9   # tier occupancy that triggers LRU
    host_low_watermark: float = 0.7    # ...eviction down to this level
    # --- gManager / Algorithm 1 --------------------------------------- #
    schedule_every: int = 4        # cluster steps between plan rounds
    heartbeat_timeout: float = 3.0
    beta_thres: int | None = None  # debtor batch threshold (None => max_batch)
    mem_util_thres: float = 0.8    # creditor memory threshold
    avg_new_req_len: int = 512     # batch-growth credit per freed token
    max_stripes: int = 8           # creditors one plan may fan out to
    reclaim_horizon_s: float = 1.0  # amortization window of reclaim gain
    # --- frontend (LLMServer) ----------------------------------------- #
    max_waiting: int = 256         # admission-queue bound (backpressure)
    admission_policy: str = "queue"  # "queue" | "reject" when bounded out
    # --- overload survival (preemption) -------------------------------- #
    overload: OverloadPolicy = OverloadPolicy()  # pause/spill/resume knobs
    # --- fault tolerance ----------------------------------------------- #
    faults: FaultPolicy = FaultPolicy()  # detection/retry/replay budgets

    def __post_init__(self):
        if self.admission_policy not in ("queue", "reject"):
            raise ValueError(
                f"admission_policy must be 'queue' or 'reject', got "
                f"{self.admission_policy!r}")
        if self.max_local_len < 2 * self.block_size:
            raise ValueError("max_local_len must cover >= 2 blocks")
        if self.host_tier_blocks > 0 and not self.prefix_cache:
            raise ValueError("host_tier_blocks requires prefix_cache=True"
                             " (the radix cache is the tier's index)")
        if not 0.0 < self.host_low_watermark <= self.host_high_watermark \
                <= 1.0:
            raise ValueError("need 0 < host_low_watermark <= "
                             "host_high_watermark <= 1")

    @property
    def beta_threshold(self) -> int:
        """Algorithm-1 debtor batch threshold (defaults to max_batch)."""
        return self.max_batch if self.beta_thres is None else self.beta_thres

    def replace(self, **overrides) -> "ServingConfig":
        """Derive a variant config (frozen dataclass ``replace``)."""
        return dataclasses.replace(self, **overrides)

    # --- presets ------------------------------------------------------ #
    @classmethod
    def smoke(cls, **overrides) -> "ServingConfig":
        """CPU smoke scale: tiny pools so every path triggers fast."""
        base = dict(n_instances=2, max_batch=3, max_local_len=32,
                    pool_blocks=48, block_size=8, prefill_chunk=8,
                    move_chunk_tokens=8, schedule_every=4,
                    heartbeat_timeout=1e9, avg_new_req_len=16,
                    max_waiting=64)
        base.update(overrides)
        return cls(**base)

    @classmethod
    def v5e(cls, **overrides) -> "ServingConfig":
        """Paper-regime deployment shape (one v5e-8 instance pool)."""
        base = dict(n_instances=4, max_batch=64, max_local_len=32_768,
                    pool_blocks=8192, block_size=16, prefill_chunk=512,
                    move_chunk_tokens=256, schedule_every=8,
                    heartbeat_timeout=3.0, avg_new_req_len=512,
                    max_waiting=1024)
        base.update(overrides)
        return cls(**base)
