"""Cross-request radix prefix cache over content-hashed block chains.

Multi-tenant serving repeats prefixes constantly — shared system
prompts, few-shot templates, multi-turn history. Under the paper's
pooled-memory economy a cached prefix is the perfect span: KV that is
already resident somewhere in the cluster hierarchy, so admitting a
request that starts with it costs table edits (device hit), an
asynchronous H2D chain (host-tier hit), or a D2D block copy (peer
hit) — never prefill FLOPs.

The index is a radix tree over FULL blocks: each node represents one
``block_size``-token chunk, keyed under its parent by the chunk's token
tuple and identified globally by a chained content hash
(``block_hash(parent_hash, tokens)`` — also the node's key in the
:class:`~repro.serving.hosttier.HostKVTier`). A node's storage is any
of: device replicas (``inst_id -> block id``, each holding one
refcounted reference in that instance's ``BlockAllocator``) and/or one
host-tier frame. Admission walks the longest cached prefix, PINS every
matched node (``refcount`` = live request pins; recorded per request so
release is exactly-once), and returns local block ids the engine
attaches via ``RankKVPool.attach_shared`` — prefill then streams only
the uncached tail. Finished requests insert their chain back
(``insert_chain`` adopts the very frames, zero copies), which is how
blocks get a second life instead of being dropped; device pressure
evicts unpinned LRU replicas, spilling them to the host tier first when
one is configured — the spill half of the paper's memory hierarchy.

Invariants (property-tested in tests/test_prefix_cache.py):
  * a pinned node (refcount > 0) is never evicted, and pins cover the
    whole matched path, so an unpinned node has no pinned descendants;
  * every device replica holds exactly one allocator reference — frames
    return to the free list only when the cache AND every sharing
    request have released them;
  * a node with no storage left is unreachable and its whole subtree is
    dropped (every replica freed, every host frame dropped) — the tree
    stays closed under parents.

Hash collisions: children are keyed by the literal token tuple, so a
colliding 64-bit chain hash can never serve wrong KV — it could only
alias two host-tier frames, which we accept at ~2^-64 odds.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.faults import FrameCorruptionError, TransferError
from repro.serving.hosttier import HostKVTier

# Allocator owner id of frames held by the cache (never a real req_id).
CACHE_OWNER = -2

_ROOT_HASH = hash(("radix-root",))


def block_hash(parent_hash: int, tokens: Sequence[int]) -> int:
    """Chained content hash of one full block given its prefix's hash."""
    return hash((parent_hash, tuple(int(t) for t in tokens)))


@dataclass
class RadixNode:
    """One block-granular radix-tree node (a content-hashed KV block).

    ``replicas`` maps instance id -> device block; ``on_host`` marks a
    host-tier copy; ``refcount`` pins the chain while requests use it.
    """

    tokens: Tuple[int, ...]                    # this block's token chunk
    hash: int
    parent: Optional["RadixNode"]
    depth: int = 0                             # blocks from root (root=0)
    children: Dict[Tuple[int, ...], "RadixNode"] = field(
        default_factory=dict)
    replicas: Dict[int, int] = field(default_factory=dict)
    on_host: bool = False
    refcount: int = 0                          # live request pins
    tick: int = 0                              # LRU clock


@dataclass
class PrefixCacheStats:
    """Counters surfaced through ``server.metrics`` (cache_* keys)."""

    lookups: int = 0
    hits: int = 0                 # lookups that matched >= 1 block
    hit_blocks: int = 0
    cow_copies: int = 0
    inserted_nodes: int = 0
    device_evictions: int = 0


class RadixPrefixCache:
    """Cluster-wide radix index over cached KV block chains.

    ``cluster`` provides ``engines`` (inst_id -> engine with
    ``rmanager.pool.alloc``, ``read_block_rows``, ``write_block_rows``,
    ``stats``), ``stager`` and ``block_size`` — the real ``Cluster`` or
    a test stub.
    """

    def __init__(self, cluster, host_tier: Optional[HostKVTier] = None):
        self.cluster = cluster
        self.bs = cluster.block_size
        self.tier = host_tier
        if host_tier is not None:
            host_tier.on_evict = self._on_host_evict
            host_tier.evictable_fn = self._host_evictable
        self.root = RadixNode((), _ROOT_HASH, None)
        self._nodes: Dict[int, RadixNode] = {}      # hash -> node
        self._pins: Dict[int, List[RadixNode]] = {}  # req_id -> path
        self._clock = 0
        self.stats = PrefixCacheStats()

    # ----------------------------------------------------------------- #
    def _touch(self, node: RadixNode) -> None:
        self._clock += 1
        node.tick = self._clock

    def _live_insts(self) -> set:
        dead = getattr(self.cluster, "_dead", set())
        return {i for i in self.cluster.engines if i not in dead}

    # --- admission walk ---------------------------------------------- #
    def acquire(self, inst_id: int, req_id: int, tokens: Sequence[int],
                max_blocks: int) -> List[int]:
        """Walk the longest cached prefix of ``tokens`` and materialize
        it on ``inst_id``: device hit = reuse the frame (table edit
        only), host hit = async H2D prefetch into a fresh frame, peer
        hit = D2D block copy. Every matched node is pinned under
        ``req_id`` (released exactly once by :meth:`release`). Returns
        the sequence-ordered local block ids of the matched prefix."""
        assert req_id not in self._pins, "acquire without release"
        self.stats.lookups += 1
        node = self.root
        blocks: List[int] = []
        pinned: List[RadixNode] = []
        n = min(len(tokens) // self.bs, max_blocks)
        for i in range(n):
            chunk = tuple(int(t) for t in
                          tokens[i * self.bs:(i + 1) * self.bs])
            child = node.children.get(chunk)
            if child is None:
                break
            blk = self._materialize(inst_id, child)
            if blk is None:
                break
            child.refcount += 1
            self._touch(child)
            pinned.append(child)
            blocks.append(blk)
            node = child
        if pinned:
            self._pins[req_id] = pinned
            self.stats.hits += 1
            self.stats.hit_blocks += len(blocks)
        return blocks

    def release(self, req_id: int) -> None:
        """Unpin every node ``req_id`` acquired — exactly once,
        idempotent (the pin list is popped)."""
        for node in self._pins.pop(req_id, ()):
            assert node.refcount > 0, "release without matching pin"
            node.refcount -= 1

    def _materialize(self, inst_id: int, node: RadixNode) -> Optional[int]:
        """A device block id for ``node`` on ``inst_id``, creating a
        replica from a peer (D2D) or the host tier (H2D) if needed."""
        blk = node.replicas.get(inst_id)
        if blk is not None:
            return blk
        eng = self.cluster.engines[inst_id]
        alloc = eng.rmanager.pool.alloc
        got = alloc.alloc(1, CACHE_OWNER)
        if got is None:
            if self.evict_device(inst_id, 1):
                got = alloc.alloc(1, CACHE_OWNER)
            if got is None:
                return None
        blk = got[0]
        live = self._live_insts()
        src = next(((i, b) for i, b in node.replicas.items() if i in live),
                   None)
        if src is not None:
            # Peer device replica: block-copy D2D, dispatched async.
            si, sb = src
            k, v = self.cluster.engines[si].read_block_rows(sb)
            eng.write_block_rows(blk, k, v)
            eng.stats.kv_moved += int(k.size * k.dtype.itemsize
                                      + v.size * v.dtype.itemsize)
            self.cluster.stager.stage((eng.pool_k, eng.pool_v),
                                      tag="prefetch")
        elif node.on_host and self.tier is not None:
            try:
                frame = self.tier.get(node.hash)  # stall-aware
            except (TransferError, FrameCorruptionError):
                # Unfetchable or hash-mismatched host frame: treat it
                # as LOST rather than poisoning decode with wrong KV.
                # The shortened cached prefix means admission simply
                # re-prefills those tokens — token-replay fallback.
                self.tier.drop(node.hash)
                frame = None
            if frame is None:                     # raced a host eviction
                node.on_host = False
                alloc.free([blk])
                return None
            k, v = frame
            eng.write_block_rows(blk, k, v)
            eng.stats.host_prefetch_bytes += int(k.nbytes + v.nbytes)
            self.cluster.stager.stage((eng.pool_k, eng.pool_v),
                                      tag="prefetch")
        else:
            alloc.free([blk])                     # storage-less node
            return None
        node.replicas[inst_id] = blk
        return blk

    # --- insertion (finished requests) -------------------------------- #
    def insert_chain(self, inst_id: int, tokens: Sequence[int],
                     blocks: Sequence[int]) -> int:
        """Adopt a finished request's full local blocks as cached nodes.

        ``tokens``: the content whose KV the chain holds (prompt +
        generated minus the last sampled token); ``blocks``: the
        request's sequence-ordered device blocks on ``inst_id``. Only
        the leading FULL blocks are inserted. Adoption is zero-copy:
        the frame gains one cache-held allocator reference and survives
        the request's release. Returns the number of frames adopted."""
        return self.insert_chain_multi([(inst_id, b) for b in blocks],
                                       tokens)

    def insert_chain_multi(self, placements: Sequence[Tuple[int, int]],
                           tokens: Sequence[int]) -> int:
        """``insert_chain`` where block i may live on ANY instance.

        ``placements``: the sequence-ordered ``(inst_id, block_id)``
        GLOBAL chain of a finished request — for a creditor-spanning
        request that is its striped ``PrefixSink`` frames followed by
        the owner's local tail (``InstanceEngine.req_chain``). Each
        adopted frame gains one cache-held reference in ITS OWN
        instance's allocator, so a striped span survives both the
        owner's release and the cluster's ``drop_hosted`` — and a later
        request admitted ANYWHERE warm-hits it (``_materialize`` D2D-
        copies from whichever replica instance is closest). The walk
        stops at the first block on a dead instance: a radix prefix must
        stay gap-free."""
        live = self._live_insts()
        node = self.root
        adopted = 0
        n = min(len(tokens) // self.bs, len(placements))
        for i in range(n):
            inst_id, blk = placements[i]
            if inst_id not in live:
                break
            chunk = tuple(int(t) for t in
                          tokens[i * self.bs:(i + 1) * self.bs])
            child = node.children.get(chunk)
            if child is None:
                child = RadixNode(chunk, block_hash(node.hash, chunk),
                                  node, depth=node.depth + 1)
                node.children[chunk] = child
                self._nodes[child.hash] = child
                self.stats.inserted_nodes += 1
            if inst_id not in child.replicas:
                alloc = self.cluster.engines[inst_id].rmanager.pool.alloc
                alloc.incref([blk])
                alloc.rebind(blk, CACHE_OWNER)
                child.replicas[inst_id] = blk
                adopted += 1
            self._touch(child)
            node = child
        return adopted

    # --- eviction ------------------------------------------------------ #
    def evictable(self, inst_id: int) -> int:
        """Unpinned device replicas on ``inst_id`` — frames an eviction
        pass could return to the allocator."""
        return sum(1 for nd in self._nodes.values()
                   if inst_id in nd.replicas and nd.refcount == 0)

    def pinned_blocks(self, inst_id: int) -> int:
        """Cached device blocks on ``inst_id`` pinned by live requests."""
        return sum(1 for nd in self._nodes.values()
                   if inst_id in nd.replicas and nd.refcount > 0)

    def device_blocks(self, inst_id: int) -> int:
        """All cached device blocks resident on ``inst_id``."""
        return sum(1 for nd in self._nodes.values()
                   if inst_id in nd.replicas)

    def evict_device(self, inst_id: int, n_blocks: int) -> int:
        """Free >= ``n_blocks`` device frames on ``inst_id`` by evicting
        unpinned replicas in LRU order, spilling each to the host tier
        first when one is configured (the D2H copy is dispatched async
        and lands behind compute). Returns the frames actually freed."""
        eng = self.cluster.engines[inst_id]
        alloc = eng.rmanager.pool.alloc
        victims = sorted((nd for nd in self._nodes.values()
                          if inst_id in nd.replicas and nd.refcount == 0),
                         key=lambda nd: nd.tick)
        freed = 0
        for node in victims:
            if freed >= n_blocks:
                break
            if node.hash not in self._nodes or node.refcount:
                continue                 # dropped by a cascading delete
            blk = node.replicas.get(inst_id)
            if blk is None:
                continue
            if self.tier is not None and not node.on_host:
                k, v = eng.read_block_rows(blk)
                if self.tier.put(node.hash, k, v):
                    node.on_host = True
                    eng.stats.host_spill_bytes += int(
                        k.size * k.dtype.itemsize
                        + v.size * v.dtype.itemsize)
                # The put can trip the host high watermark, and the LRU
                # callback may _drop_subtree an ancestor — taking this
                # node (and its already-freed frame) with it.
                if node.hash not in self._nodes \
                        or inst_id not in node.replicas:
                    continue
            del node.replicas[inst_id]
            alloc.free([blk])
            freed += 1
            self.stats.device_evictions += 1
            if not node.replicas and not node.on_host:
                freed += self._drop_subtree(node, count_inst=inst_id)
        return freed

    def _drop_subtree(self, node: RadixNode,
                      count_inst: Optional[int] = None) -> int:
        """Remove ``node`` and every descendant from the tree, freeing
        all their device replicas and host frames (a storage-less node
        makes its subtree unreachable). Returns frames freed on
        ``count_inst``."""
        freed = 0
        stack = [node]
        if node.parent is not None:
            node.parent.children.pop(node.tokens, None)
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            nd.children = {}
            for i, blk in list(nd.replicas.items()):
                eng = self.cluster.engines.get(i)
                if eng is not None:
                    eng.rmanager.pool.alloc.free([blk])
                if i == count_inst:
                    freed += 1
            nd.replicas = {}
            if nd.on_host and self.tier is not None:
                self.tier.drop(nd.hash)
            nd.on_host = False
            self._nodes.pop(nd.hash, None)
        return freed

    def purge_instance(self, inst_id: int) -> int:
        """Quarantine cleanup for a dead rank: pop every cache replica
        on ``inst_id`` and return its allocator reference (the rank's
        pool is being drained wholesale), dropping any node left with
        no storage at all. Host frames and live-rank replicas survive —
        they stay warm-hittable. Returns replicas purged."""
        purged = 0
        for node in list(self._nodes.values()):
            if node.hash not in self._nodes:
                continue                 # removed by a cascading delete
            blk = node.replicas.pop(inst_id, None)
            if blk is None:
                continue
            eng = self.cluster.engines.get(inst_id)
            if eng is not None:
                eng.rmanager.pool.alloc.free([blk])
            purged += 1
            if not node.replicas and not node.on_host:
                self._drop_subtree(node)
        return purged

    # --- host-tier callbacks ------------------------------------------- #
    def _host_evictable(self, key: int) -> bool:
        node = self._nodes.get(key)
        return node is None or node.refcount == 0

    def _on_host_evict(self, key: int) -> None:
        """Host-tier LRU dropped ``key``'s frame: if the node has no
        device replica left either, its subtree is unreachable."""
        node = self._nodes.get(key)
        if node is None:
            return
        node.on_host = False
        if not node.replicas:
            self._drop_subtree(node)

    # --- introspection ------------------------------------------------- #
    @property
    def num_nodes(self) -> int:
        """Radix nodes currently in the tree."""
        return len(self._nodes)

    def host_blocks(self) -> int:
        """Host-tier frames holding cache replicas (0 without a tier)."""
        return self.tier.used_blocks if self.tier is not None else 0


__all__ = ["RadixPrefixCache", "RadixNode", "PrefixCacheStats",
           "block_hash", "CACHE_OWNER"]
