"""Request-lifecycle serving frontend: ``LLMServer`` + ``RequestHandle``.

The paper's whole subject is DYNAMIC traffic — requests with wildly
different context lengths arriving, growing, and finishing at different
times — so the public serving API is a request lifecycle, not a step
loop:

    server = LLMServer(params, cfg, ServingConfig.smoke())
    h = server.submit(prompt, SamplingParams(max_new_tokens=32),
                      priority=1, deadline_s=2.0)
    for tok in h.tokens():          # incremental stream (engine emits)
        ...
    h.result(); h.status; h.metrics; h.cancel()

and an OPEN-LOOP event pump for trace-driven evaluation:

    stats = server.run(arrivals, until=30.0)
    stats["ttft_p99"], stats["tbt_p99"], ...

``submit`` applies admission backpressure (a bounded waiting queue with
a reject-vs-queue policy from ``ServingConfig``); the dispatcher orders
waiting requests by priority and deadline proximity and feeds the same
urgency into the gManager's Algorithm-1 planning, so near-deadline
debtors are offloaded/served first. Cancellation propagates through
every layer (engine slot, in-flight streaming prefill, creditor-hosted
spans, planned moves) — see ``Cluster.cancel``.

With ``ServingConfig.overload.enabled`` the frontend also survives
sustained overload instead of queueing through it: when urgent arrivals
find zero free slots, ``_overload_control`` pauses SLO-slack victims
(their KV spills byte-for-byte to a pinned host tier — see
``repro.serving.preempt``) and hands the freed slots to the arrivals;
parked requests resume with byte-identical KV once capacity returns.
Every ``submit`` additionally feeds the gManager's EWMA arrival
estimator, which replaces the static ``avg_new_req_len`` knob in
Algorithm 1's planning.

The cluster's ``step()`` loop still exists underneath — it is the
INTERNAL execution engine this frontend drives.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.cluster import Cluster
from repro.serving.config import ServingConfig
from repro.serving.perfmodel import InstancePerfModel
from repro.serving.request import (Request, RequestIdAllocator,
                                   RequestState, SamplingParams)


@dataclass
class Arrival:
    """One trace event for the open-loop pump: a prompt that becomes
    available for admission at ``at`` seconds after ``run()`` starts."""
    at: float
    prompt: List[int]
    sampling: SamplingParams = field(default_factory=SamplingParams)
    priority: int = 0
    deadline_s: Optional[float] = None


class RequestHandle:
    """Caller's view of one submitted request's lifecycle."""

    def __init__(self, server: "LLMServer", req: Request):
        self._server = server
        self._req = req

    @property
    def req_id(self) -> int:
        """The underlying request's id."""
        return self._req.req_id

    @property
    def status(self) -> RequestState:
        """Current lifecycle state of the request."""
        return self._req.state

    @property
    def done(self) -> bool:
        """True once the request reached a terminal state."""
        return self._req.done

    def tokens(self, max_steps: int = 100_000) -> Iterator[int]:
        """Incremental token stream, backed by the engine's emit path.

        Yields every token already generated, then drives the server
        until the next token lands (or the request reaches a terminal
        state). Safe to interleave with other handles' iterators — each
        ``server.step()`` advances EVERY in-flight request.
        """
        seen = 0
        steps = 0
        while True:
            out = self._req.output
            while seen < len(out):
                yield out[seen]
                seen += 1
            if self._req.done:
                return
            if steps >= max_steps:
                raise RuntimeError(
                    f"req {self._req.req_id} made no progress in "
                    f"{max_steps} steps (state={self._req.state})")
            self._server.step()
            steps += 1

    def result(self, max_steps: int = 100_000) -> List[int]:
        """Block (drive the server) until terminal; return the output
        tokens. Raises on FAILED; a CANCELLED request returns whatever
        it produced before the cancel."""
        for _ in self.tokens(max_steps=max_steps):
            pass
        if self._req.state == RequestState.FAILED:
            raise RuntimeError(f"req {self._req.req_id} failed "
                               f"(pool exhaustion or infeasible placement)")
        return list(self._req.output)

    def cancel(self) -> bool:
        """Cancel this request wherever it is in its lifecycle."""
        return self._server.cancel(self._req.req_id)

    @property
    def metrics(self) -> Dict[str, float]:
        """Per-request latency metrics (seconds, monotonic domain):
        ``ttft`` (first token after arrival), ``tbt_mean``/``tbt_max``
        over inter-token gaps, ``e2e`` (arrival -> terminal), plus the
        raw ``arrival_time``/``finish_time`` stamps."""
        r = self._req
        out: Dict[str, float] = {
            "arrival_time": r.arrival_time,
            "finish_time": r.finish_time if r.finish_time is not None
            else float("nan"),
            "n_tokens": float(len(r.output)),
        }
        tt = r.token_times
        out["ttft"] = (tt[0] - r.arrival_time) if tt else float("nan")
        gaps = np.diff(tt) if len(tt) >= 2 else np.asarray([])
        out["tbt_mean"] = float(gaps.mean()) if gaps.size else float("nan")
        out["tbt_max"] = float(gaps.max()) if gaps.size else float("nan")
        out["e2e"] = (r.finish_time - r.arrival_time) \
            if r.finish_time is not None else float("nan")
        return out


class LLMServer:
    """Serving frontend: admission queue + dispatcher over a Cluster."""

    def __init__(self, params, cfg: ModelConfig,
                 config: Optional[ServingConfig] = None, *,
                 perf: Optional[InstancePerfModel] = None,
                 mesh=None, layout=None):
        self.config = config if config is not None else ServingConfig()
        self.cluster = Cluster(params, cfg, self.config, perf=perf,
                               mesh=mesh, layout=layout)
        self._ids = RequestIdAllocator()
        self._handles: Dict[int, RequestHandle] = {}
        self._queue: List[Request] = []      # admitted, not yet dispatched
        self.rejected: int = 0               # bounded-queue rejections

    # --- submission ---------------------------------------------------- #
    def submit(self, prompt: Sequence[int],
               sampling: Optional[SamplingParams] = None, *,
               priority: int = 0, deadline_s: Optional[float] = None,
               arrival_time: Optional[float] = None) -> RequestHandle:
        """Admit one request; returns its lifecycle handle.

        Backpressure: when the waiting queue is at ``config.max_waiting``
        the ``admission_policy`` decides — "queue" accepts anyway (the
        bound only throttles DISPATCH), "reject" retires the request
        immediately as FAILED (open-loop load shedding; the handle's
        status says so and ``server.rejected`` counts them).
        """
        req = Request(prompt=list(prompt),
                      sampling=sampling if sampling is not None
                      else SamplingParams(),
                      req_id=self._ids.next_id(),
                      priority=priority, deadline_s=deadline_s)
        req.arrival_time = time.monotonic() if arrival_time is None \
            else arrival_time
        handle = RequestHandle(self, req)
        self._handles[req.req_id] = handle
        # Every arrival (even one about to be shed) feeds the gManager's
        # EWMA traffic estimator: expected KV footprint is the prompt
        # plus the decode budget — the worst case the pool must plan for.
        self.cluster.gmanager.observe_arrival(
            req.arrival_time,
            len(req.prompt) + req.sampling.max_new_tokens)
        if (self.config.admission_policy == "reject"
                and self._waiting_count() >= self.config.max_waiting):
            req.state = RequestState.FAILED
            req.finish_time = time.monotonic()
            self.rejected += 1
            return handle
        self._queue.append(req)
        return handle

    def cancel(self, req_id: int) -> bool:
        """Cancel a request whether it is still queued here or already
        inside the cluster."""
        for req in self._queue:
            if req.req_id == req_id:
                self._queue.remove(req)
                req.cancelled = True
                req.state = RequestState.CANCELLED
                req.finish_time = time.monotonic()
                return True
        return self.cluster.cancel(req_id)

    # --- dispatch ------------------------------------------------------ #
    def _waiting_count(self) -> int:
        return len(self._queue) + sum(
            len(e.waiting) for i, e in self.cluster.engines.items()
            if i not in self.cluster._dead)

    def _free_slots(self) -> int:
        """Cluster-wide dispatch budget: each live engine contributes
        the slots its own waiting queue has not already claimed (an
        overloaded engine contributes zero — it never cancels another
        engine's free capacity)."""
        free = 0
        for i, eng in self.cluster.engines.items():
            if i in self.cluster._dead:
                continue
            free += max(0, sum(1 for s in eng.slots if s is None)
                        - len(eng.waiting))
        return free

    def _dispatch(self, now: Optional[float] = None) -> None:
        """Hand queued requests to the cluster, most urgent first, only
        as many as have a real chance of a slot this step (admission
        backpressure — queued work stays HERE, reorderable by urgency,
        instead of piling into the engines' FCFS queues)."""
        if not self._queue:
            return
        now = time.monotonic() if now is None else now
        budget = self._free_slots()
        if budget <= 0:
            return
        self._queue.sort(key=lambda r: (-r.urgency(now), r.arrival_time))
        for req in self._queue[:budget]:
            self.cluster.submit(req, now=now)
        del self._queue[:budget]

    def _overload_control(self, now: float) -> None:
        """Preempt-for-queue: when dispatch left urgent requests queued
        with zero free slots, pause SLO-slack victims to make room.

        Runs after ``_dispatch`` each step (no-op unless the overload
        policy is enabled). Each queued request, most urgent first, asks
        the preemptor for a victim it out-ranks whose charged slack
        survives the detour; the victim's freed slot takes the queued
        request directly (``submit_to``), pairing preemption with the
        arrival that justified it. The preemptor's resume path is told
        the remaining queue's best urgency (``queue_pressure``) so
        parked requests never steal capacity the queue is entitled to."""
        pre = self.cluster.preemptor
        if pre is None:
            return
        if not self._queue:
            pre.queue_pressure = None
            return
        if self._free_slots() <= 0:
            self._queue.sort(
                key=lambda r: (-r.urgency(now), r.arrival_time))
            for req in list(self._queue):
                inst = pre.pause_for(req, now=now)
                if inst is None:
                    break           # no eligible victim for anyone less
                self._queue.remove(req)
                self.cluster.submit_to(req, inst, now=now)
        pre.queue_pressure = max(
            (r.urgency(now) for r in self._queue), default=None)

    # --- execution ----------------------------------------------------- #
    def step(self, now: Optional[float] = None) -> int:
        """One frontend iteration: dispatch, overload control (paused
        victims / preempted slots when enabled), then one cluster step."""
        now = time.monotonic() if now is None else now
        self._dispatch(now)
        self._overload_control(now)
        return self.cluster.step(now=now)

    def drain(self, max_steps: int = 10_000) -> int:
        """Drive until every submitted request is terminal (closed-loop
        convenience for examples/tests). Returns steps taken."""
        steps = 0
        active = [h for h in self._handles.values() if not h.done]
        while steps < max_steps:
            active = [h for h in active if not h.done]
            if not active:
                break
            self.step()
            steps += 1
        return steps

    def evict_terminal(self) -> int:
        """Drop terminal requests from the server/cluster maps so a
        long-lived server does not retain every prompt/output forever.
        Handles the caller still holds stay valid — they reference the
        Request directly. Returns how many were evicted."""
        gone = [rid for rid, h in self._handles.items() if h.done]
        for rid in gone:
            self._handles.pop(rid, None)
            self.cluster.requests.pop(rid, None)
        return len(gone)

    @property
    def handles(self) -> List[RequestHandle]:
        """Live (unreaped) request handles, including queued ones."""
        return list(self._handles.values())

    @property
    def metrics(self) -> Dict[str, float]:
        """Cluster-wide occupancy counters: device-pool blocks (total /
        used / free), prefix-cache footprint (device replicas, pinned by
        live requests), host-tier occupancy, and cumulative spill /
        prefetch / hit traffic — plus fault-tolerance counters (dead
        ranks, token-replay recoveries, replayed tokens, transfer
        retries/failures, frame corruptions). Cache, host-tier, and
        fault entries are present (as zeros) even when the features are
        off/quiet, so dashboards keyed on the names never miss."""
        cl = self.cluster
        total = used = free = 0
        spill = prefetch = hit_toks = 0
        for i, eng in cl.engines.items():
            if i in cl._dead:
                continue
            alloc = eng.rmanager.pool.alloc
            total += alloc.num_blocks
            used += alloc.used_count
            free += alloc.free_count
            spill += eng.stats.host_spill_bytes
            prefetch += eng.stats.host_prefetch_bytes
            hit_toks += eng.stats.cache_hit_tokens
        out: Dict[str, float] = {
            "device_blocks_total": float(total),
            "device_blocks_used": float(used),
            "device_blocks_free": float(free),
            "cache_device_blocks": 0.0,
            "cache_pinned_blocks": 0.0,
            "cache_hit_tokens": float(hit_toks),
            "host_blocks_used": 0.0,
            "host_blocks_capacity": 0.0,
            "host_spill_bytes": float(spill),
            "host_prefetch_bytes": float(prefetch),
        }
        if cl.prefix_cache is not None:
            live = [i for i in cl.engines if i not in cl._dead]
            out["cache_device_blocks"] = float(sum(
                cl.prefix_cache.device_blocks(i) for i in live))
            out["cache_pinned_blocks"] = float(sum(
                cl.prefix_cache.pinned_blocks(i) for i in live))
        if cl.host_tier is not None:
            out["host_blocks_used"] = float(cl.host_tier.used_blocks)
            out["host_blocks_capacity"] = float(cl.host_tier.capacity)
        # Overload-survival counters (zeros when the policy is off) and
        # the live traffic estimate feeding Algorithm 1.
        out.update({
            "preemptions": 0.0,
            "preempt_resumes": 0.0,
            "paused_now": 0.0,
            "preempt_tier_blocks_used": 0.0,
            "arrival_rate_hz": cl.gmanager.arrivals.rate_hz,
            "avg_new_req_len_est":
                float(cl.gmanager.arrivals.avg_new_req_len),
        })
        if cl.preemptor is not None:
            out["preemptions"] = float(cl.preemptor.stats.preemptions)
            out["preempt_resumes"] = float(cl.preemptor.stats.resumes)
            out["paused_now"] = float(len(cl.preemptor.paused))
            out["preempt_tier_blocks_used"] = float(
                cl.preemptor.tier.used_blocks)
        # Fault-tolerance counters: detection, token-replay recovery,
        # and transfer retry/failure totals (stager + every host tier).
        fs = cl.fault_stats
        retries = float(sum(cl.stager.retries.values()))
        failures = float(sum(cl.stager.failures.values()))
        corruptions = 0.0
        tiers = [cl.host_tier]
        if cl.preemptor is not None:
            tiers.append(cl.preemptor.tier)
        for tier in tiers:
            if tier is not None:
                retries += float(tier.stats.fetch_retries)
                failures += float(tier.stats.fetch_failures)
                corruptions += float(tier.stats.corruptions)
        out.update({
            "dead_instances": float(len(cl._dead)),
            "fault_recoveries": float(fs.recoveries),
            "fault_failed_recoveries": float(fs.failed_recoveries),
            "replayed_tokens": float(fs.replayed_tokens),
            "move_leg_failures": float(fs.move_leg_failures),
            "transfer_retries": retries,
            "transfer_failures": failures,
            "host_frame_corruptions": corruptions,
        })
        return out

    # --- open-loop event pump ------------------------------------------ #
    def run(self, arrivals: Iterable[Arrival], *,
            until: Optional[float] = None,
            max_steps: int = 1_000_000) -> Dict[str, float]:
        """Serve a timestamped arrival trace open-loop.

        Arrivals are submitted when the wall clock passes their ``at``
        offset (the arrival process is NOT gated on service progress —
        the open-loop regime LoongServe/Medha evaluate under); the pump
        steps the cluster continuously and returns aggregate frontend
        metrics. ``until`` stops the pump (wall seconds after start)
        even if requests are still in flight; otherwise it runs until
        every arrival is terminal.
        """
        pending = sorted(arrivals, key=lambda a: a.at)
        t0 = time.monotonic()
        submitted: List[RequestHandle] = []
        in_flight: List[RequestHandle] = []   # pruned as handles finish
        steps = 0
        while steps < max_steps:
            now = time.monotonic()
            rel = now - t0
            while pending and pending[0].at <= rel:
                a = pending.pop(0)
                h = self.submit(a.prompt, a.sampling, priority=a.priority,
                                deadline_s=a.deadline_s, arrival_time=now)
                submitted.append(h)
                in_flight.append(h)
            if until is not None and rel >= until:
                break
            in_flight = [h for h in in_flight if not h.done]
            if not in_flight:
                if not pending:
                    break
                if not self._queue:
                    # Idle gap in the trace: sleep to the next arrival.
                    time.sleep(min(pending[0].at - rel, 0.05))
                    continue
            self.step(now=now)
            steps += 1
        return self.frontend_metrics(submitted,
                                     wall_s=time.monotonic() - t0)

    # --- aggregate metrics --------------------------------------------- #
    @staticmethod
    def frontend_metrics(handles: Sequence[RequestHandle],
                         wall_s: float,
                         now: Optional[float] = None) -> Dict[str, float]:
        """Per-request TTFT/TBT pooled into the percentile metrics the
        paper-adjacent frontends (LoongServe, Medha) report.

        A deadline only counts as missed once it is actually missable:
        the request finished past it, or is still unfinished at ``now``
        (monotonic) with the deadline already behind — an in-flight
        request whose deadline lies in the future is not a miss."""
        now = time.monotonic() if now is None else now
        ttfts, tbts, finished, failed, cancelled, toks = \
            [], [], 0, 0, 0, 0
        deadline_miss = preempted = goodput = dl_total = dl_met = 0
        for h in handles:
            r = h._req
            toks += len(r.output)
            if r.state == RequestState.FINISHED:
                finished += 1
            elif r.state == RequestState.FAILED:
                failed += 1
            elif r.state == RequestState.CANCELLED:
                cancelled += 1
            if r.preemptions > 0:
                preempted += 1
            if r.token_times:
                ttfts.append(r.token_times[0] - r.arrival_time)
                tbts.extend(np.diff(r.token_times))
            dl = r.deadline_at
            if dl is not None and (r.finish_time or now) > dl:
                deadline_miss += 1
            # Deadline GOODPUT: a request contributes only by finishing
            # in time (no deadline = any finish counts). The bench's
            # preemption-vs-baseline gate compares this.
            on_time = r.state == RequestState.FINISHED and (
                dl is None or (r.finish_time or now) <= dl)
            goodput += int(on_time)
            if dl is not None:
                dl_total += 1
                dl_met += int(on_time)

        def pct(xs, q):
            """Percentile helper tolerating empty series (-> nan)."""
            return float(np.percentile(xs, q)) if len(xs) else float("nan")

        return {
            "n_requests": float(len(handles)),
            "finished": float(finished),
            "failed": float(failed),
            "cancelled": float(cancelled),
            "deadline_missed": float(deadline_miss),
            "deadline_goodput": goodput / max(1, len(handles)),
            "slo_attainment": (dl_met / dl_total) if dl_total
            else float("nan"),
            "preempted": float(preempted),
            "tokens": float(toks),
            "throughput_tok_s": toks / max(wall_s, 1e-9),
            "ttft_p50": pct(ttfts, 50),
            "ttft_p99": pct(ttfts, 99),
            "tbt_p50": pct(tbts, 50),
            "tbt_p99": pct(tbts, 99),
            "wall_s": wall_s,
        }
