"""Mesh-level serving steps: paged DistAttention decode + pooled prefill.

Written in global view with sharding constraints so GSPMD materializes
the paper's communication pattern:

  * The KV pool is [L, NP, NB, bs, K, hd] with the NP axis sharded over
    ``pool_axes`` (("data",) in tp_head mode — kv heads over "model" —
    or ("data","model") when kv_heads < TP, where DistAttention's
    sequence sharding REPLACES head-TP; paper §7.4).
  * Every pool shard computes a MicroAttention partial over its local
    blocks (vmap over NP == per-shard local compute), and partials merge
    with ``merge_partials`` over the NP axis — lowering to the pmax/psum
    pattern of paper Eq. 3. Queries are broadcast; KV never moves.
  * Block-table metadata is host-provided and sharded like the pool, so
    placement changes are pure data — no recompilation (DESIGN.md §2).
  * Tail appends use the cluster pool's ONE dump convention (see the
    kvpool module docstring): per-shard write indices select either the
    request's tail block (on exactly one shard) or the OUT-OF-RANGE
    sentinel NB, and every scatter passes ``mode="drop"`` — no real
    dump slot is allocated, so the sharded and per-instance pools share
    the exact [NB, bs, K, hd] layout.

``decode_step_global``/``prefill_chunk_global`` at the bottom are the
serving cluster's entry into this file: the same paged steps the
engines run in-process, but over the cluster-wide ``GlobalKVPool``
tensor ``[ranks, L, NB, bs, K, hd]`` — vmapped over the rank axis on a
single device, shard_mapped with collective LSE-merges when a mesh is
attached.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.online_softmax import (combine, finalize,
                                       merge_partials,
                                       merge_partials_collective,
                                       micro_attention_decode,
                                       micro_attention_prefill)
from repro.kernels.ref import paged_micro_attention_ref
from repro.models.attention import make_causal_core, qkv_project
from repro.models.common import apply_ffn, apply_norm
from repro.models.model import embed_tokens, unembed
from repro.models.moe import apply_moe

wsc = jax.lax.with_sharding_constraint


@dataclasses.dataclass(frozen=True)
class ServeLayout:
    """Mesh-axis assignment for the serving step."""
    batch_axes: Tuple[str, ...]          # ("data",) or ("pod","data")
    pool_axes: Tuple[str, ...]           # + ("model",) in seq_model mode
    tp_axis: str = "model"

    @property
    def seq_model(self) -> bool:
        """True when the TP axis also shards the pool's sequence dim."""
        return self.tp_axis in self.pool_axes

    @property
    def kv_head_axis(self):
        """In tp_head mode the pool's kv-head dim shards over the TP
        axis; in seq_model mode the sequence (NP) dim already covers it."""
        return None if self.seq_model else self.tp_axis

    def pool_spec(self) -> P:
        """Spec for [NP, NB, bs, K, hd] (prepend None for the L dim)."""
        return P(self.pool_axes, None, None, self.kv_head_axis, None)


def _paged_partial(q, pool_k_l, pool_v_l, tables, nblk, tails, scale):
    """vmap over pool shards: per-shard MicroAttention partial.

    q [R,H,hd] (replicated); pool_*_l [NP,NB,bs,K,hd]; tables [NP,R,MB].
    Returns merged attention output [R,H,hd] (paper Eq. 2+3).
    """
    part = jax.vmap(
        lambda pk, pv, tb, nb, tl: paged_micro_attention_ref(
            q, pk, pv, tb, nb, tl, scale=scale)
    )(pool_k_l, pool_v_l, tables, nblk, tails)
    o, m, l = part                                # [NP, R, H, hd] etc.
    og, mg, lg = merge_partials(o, m, l, axis=0)  # lowers to Eq. 3 psums
    return finalize(og, lg)


def _write_kv(pool_l, new, wblk, woff):
    """Append one token's K (or V) into each request's tail block.

    pool_l [NP, NB, bs, K, hd]; new [R, K, hd]; wblk/woff [NP, R]
    (block index NB == out-of-range sentinel on shards that don't own
    the tail; mode="drop" skips those writes — the one tail-append
    scheme, see the kvpool docstring).
    """
    def one(pool_p, wb, wo):
        return pool_p.at[wb, wo].set(new, mode="drop")
    return jax.vmap(one)(pool_l, wblk, woff)


def serve_decode_step(params, cfg: ModelConfig, layout: ServeLayout,
                      pool_k, pool_v, tables, nblk, tails, wblk, woff,
                      tokens, lens, *, capacity_factor: float = 1.25,
                      return_logits: bool = False,
                      layer_constraints=None):
    """One decode iteration for R requests over the whole mesh.

    pool_k/v: [L, NP, NB, bs, K, hd]; tables [NP, R, MB]; nblk/tails
    [NP, R]; wblk/woff [NP, R]; tokens/lens [R].
    Returns (next_tokens [R], new_pool_k, new_pool_v).
    """
    R = tokens.shape[0]
    scale = cfg.head_dim ** -0.5

    x = embed_tokens(params, cfg, tokens[:, None], None,
                     positions=lens[:, None])
    x = wsc(x, P(layout.batch_axes, None, None))

    def attn_layer(lp, x, pk_l, pv_l):
        """One layer's attention: write new KV, paged partial, merge."""
        h = apply_norm(lp["ln1"], x, cfg)
        q, k, v = qkv_project(lp["attn"], h, lens[:, None], cfg)
        pk_l = _write_kv(pk_l, k[:, 0], wblk, woff)
        pv_l = _write_kv(pv_l, v[:, 0], wblk, woff)
        out = _paged_partial(q[:, 0], pk_l, pv_l, tables, nblk, tails,
                             scale)
        out = out.reshape(R, 1, -1).astype(x.dtype) @ lp["attn"]["wo"]
        x = x + wsc(out, P(layout.batch_axes, None, None))
        return x, pk_l, pv_l

    def ffn_part(lp, x, moe):
        """One layer's FFN/MoE half."""
        h = apply_norm(lp["ln2"], x, cfg)
        if moe:
            x = x + apply_moe(lp["moe"], h, cfg, capacity_factor)
        else:
            x = x + apply_ffn(lp["ffn"], h, cfg)
        return wsc(x, P(layout.batch_axes, None, None))

    lc = layer_constraints or {}

    def make_body(moe, name):
        """Scan body factory for the ``moe``/dense layer stack."""
        def body(x, xs):
            """Scanned per-layer step (attention + FFN)."""
            lp, pk_l, pv_l = xs
            if name in lc:
                lp = lc[name](lp)
            x, pk_l, pv_l = attn_layer(lp, x, pk_l, pv_l)
            x = ffn_part(lp, x, moe)
            return x, (pk_l, pv_l)
        return body

    if cfg.family == "dense":
        x, (pk, pv) = jax.lax.scan(make_body(False, "layers"), x,
                                   (params["layers"], pool_k, pool_v))
    elif cfg.family == "moe":
        nd = cfg.first_k_dense
        if nd:
            x, (pkd, pvd) = jax.lax.scan(
                make_body(False, "dense_layers"), x,
                (params["dense_layers"], pool_k[:nd], pool_v[:nd]))
        x, (pkm, pvm) = jax.lax.scan(
            make_body(True, "moe_layers"), x,
            (params["moe_layers"], pool_k[nd:], pool_v[nd:]))
        pk = jnp.concatenate([pkd, pkm], 0) if nd else pkm
        pv = jnp.concatenate([pvd, pvm], 0) if nd else pvm
    else:
        raise ValueError("sharded decode pools KV only for attention "
                         "archs; hybrid/ssm use serve_decode_step_state")

    logits = unembed(params, cfg, x[:, 0])
    if return_logits:
        return logits, pk, pv
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, pk, pv


# --------------------------------------------------------------------- #
# Optimized decode (§Perf-1): read-only pool scan + deferred writes
# --------------------------------------------------------------------- #
def _paged_partial_fullpool(q, pool_k_l, pool_v_l, tables, nblk, tails,
                            scale):
    """In-place MicroAttention over the WHOLE local pool with an
    owner-validity mask — zero gathers, zero pool copies. Optimal when
    the pool mostly belongs to few requests (long-context decode, R~1):
    reads each pool byte exactly once; invalid slots are masked.

    (A per-slot gather formulation was tried first and REFUTED: GSPMD
    lowers the sharded-dim gather to a masked all-reduce, 268 MB/iter —
    see EXPERIMENTS.md §Perf-1 iteration 1.)
    """
    from repro.core.online_softmax import micro_attention_decode
    NP, NB, bs, K, hd = pool_k_l.shape
    R = q.shape[0]
    # Which pool slot is valid for which request, from the tables.
    oh = jax.nn.one_hot(jnp.clip(tables, 0, NB - 1), NB,
                        dtype=jnp.bool_)                # [NP,R,MB,NB]
    oh = oh & (tables >= 0)[..., None]
    block_valid = oh.any(axis=2)                        # [NP, R, NB]
    tail_blk = jnp.take_along_axis(
        tables, jnp.maximum(nblk - 1, 0)[..., None], axis=2)[..., 0]
    is_tail = (jnp.arange(NB)[None, None, :] == tail_blk[..., None]) \
        & block_valid
    limit = jnp.where(is_tail, tails[..., None], bs)    # [NP, R, NB]
    tok_ok = jnp.arange(bs)[None, None, None, :] < limit[..., None]
    mask = (block_valid[..., None] & tok_ok).reshape(NP, R, NB * bs)

    kf = pool_k_l.reshape(NP, NB * bs, K, hd)
    vf = pool_v_l.reshape(NP, NB * bs, K, hd)
    # Pool KV is shared across requests (each request masks its slots):
    # broadcast the request dim lazily (fullpool is only used for R~1).
    part = jax.vmap(lambda kb, vb, va: micro_attention_decode(
        q, jnp.broadcast_to(kb[None], (R,) + kb.shape),
        jnp.broadcast_to(vb[None], (R,) + vb.shape), va,
        scale=scale))(kf, vf, mask)
    return part                                          # [NP, ...]


def serve_decode_step_opt(params, cfg: ModelConfig, layout: ServeLayout,
                          pool_k, pool_v, tables, nblk, tails, wblk, woff,
                          tokens, lens, *, capacity_factor: float = 1.25,
                          return_logits: bool = False,
                          layer_constraints=None):
    """Beyond-paper-optimized decode (§Perf-1). Same math, new schedule:

    1. The pool rides through the layer scan READ-ONLY (xs, not carry),
       killing the per-layer double-buffer copy of the whole pool.
    2. The new token's KV joins attention as an explicit *self partial*
       merged once (Eq. 3 is associative), so no in-scan pool write.
    3. All L layers' new KV is written AFTER the scan in one batched
       scatter (k_new collected as scan ys).
    4. Per-shard attention is a block-scan (``_paged_partial_blockscan``)
       reading each pool block exactly once.

    NOTE: ``tails``/``nblk`` here describe the pool WITHOUT the new
    token (the engine increments them after the step).
    """
    from repro.core.online_softmax import (combine, finalize,
                                           micro_attention_decode)
    R = tokens.shape[0]
    scale = cfg.head_dim ** -0.5
    x = embed_tokens(params, cfg, tokens[:, None], None,
                     positions=lens[:, None])
    x = wsc(x, P(layout.batch_axes, None, None))
    lc = layer_constraints or {}

    def attn_layer(lp, x):
        """QKV projection only; the paged partial runs in the body."""
        h = apply_norm(lp["ln1"], x, cfg)
        q, k, v = qkv_project(lp["attn"], h, lens[:, None], cfg)
        return q, k, v, x

    def make_body(moe, name):
        """Scan body factory for the ``moe``/dense layer stack."""
        def body(x, xs):
            """Scanned per-layer step (attention + FFN)."""
            lp, pk_l, pv_l = xs
            if name in lc:
                lp = lc[name](lp)
            q, k, v, x = attn_layer(lp, x)
            NB_l, bs = pk_l.shape[1], pk_l.shape[2]
            if R * NB_l * bs <= 2 * NB_l * bs * tables.shape[0] \
                    and not os.environ.get('REPRO_FORCE_GATHER'):
                # Few requests own most of the pool: mask, don't gather.
                part = _paged_partial_fullpool(q[:, 0], pk_l, pv_l,
                                               tables, nblk, tails, scale)
                pooled = merge_partials(*part, axis=0)
            else:
                o_, m_, l_ = jax.vmap(
                    lambda pk, pv, tb, nb, tl: paged_micro_attention_ref(
                        q[:, 0], pk, pv, tb, nb, tl, scale=scale)
                )(pk_l, pv_l, tables, nblk, tails)
                pooled = merge_partials(o_, m_, l_, axis=0)
            self_part = micro_attention_decode(
                q[:, 0], k, v, jnp.ones((R, 1), bool), scale=scale)
            o, m, l = combine(pooled, self_part)
            out = finalize(o, l)
            out = out.reshape(R, 1, -1).astype(x.dtype) @ lp["attn"]["wo"]
            x = x + wsc(out, P(layout.batch_axes, None, None))
            h = apply_norm(lp["ln2"], x, cfg)
            if moe:
                x = x + apply_moe(lp["moe"], h, cfg, capacity_factor)
            else:
                x = x + apply_ffn(lp["ffn"], h, cfg)
            x = wsc(x, P(layout.batch_axes, None, None))
            return x, (k[:, 0], v[:, 0])
        return body

    if cfg.family == "dense":
        x, (ks, vs) = jax.lax.scan(make_body(False, "layers"), x,
                                   (params["layers"], pool_k, pool_v))
    elif cfg.family == "moe":
        nd = cfg.first_k_dense
        if nd:
            x, (kd, vd) = jax.lax.scan(
                make_body(False, "dense_layers"), x,
                (params["dense_layers"], pool_k[:nd], pool_v[:nd]))
        x, (km, vm) = jax.lax.scan(
            make_body(True, "moe_layers"), x,
            (params["moe_layers"], pool_k[nd:], pool_v[nd:]))
        ks = jnp.concatenate([kd, km], 0) if nd else km
        vs = jnp.concatenate([vd, vm], 0) if nd else vm
    else:
        raise ValueError("pooled decode is for attention archs")

    # Deferred batched write: one scatter for all layers.
    pk = jax.vmap(lambda p, n: _write_kv(p, n, wblk, woff))(pool_k, ks)
    pv = jax.vmap(lambda p, n: _write_kv(p, n, wblk, woff))(pool_v, vs)

    logits = unembed(params, cfg, x[:, 0])
    if return_logits:
        return logits, pk, pv
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, pk, pv


# --------------------------------------------------------------------- #
# Prefill: full-sequence forward + analytic round-robin pool writes
# --------------------------------------------------------------------- #
def prefill_layout(B: int, S: int, bs: int, NP: int,
                   n_data: Optional[int] = None):
    """Block placement at prefill time.

    Paper-faithful (and communication-free) layout when the batch divides
    the data axis: request b's blocks live on ITS OWN data rank — spread
    over the model sub-axis in seq_model mode — so the KV scatter is
    entirely local (the round-robin-over-all-shards layout was measured
    to all-gather the full [B*S,K,hd] KV per layer: §Perf-2 it.3).

    Returns (wblk [NP,B,S], woff [B,S], NB_loc). Non-local tokens get
    wblk == NB_loc — the OUT-OF-RANGE sentinel (the pool has exactly
    NB_loc blocks); writes use ``mode="drop"``, never a real dump slot.
    """
    nblocks = -(-S // bs)
    pos = jnp.arange(S, dtype=jnp.int32)
    blk = pos // bs                                   # [S]
    woff = jnp.broadcast_to(pos % bs, (B, S))
    p_idx = jnp.arange(NP, dtype=jnp.int32)[:, None, None]
    b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]

    if n_data and B % n_data == 0:
        n_sub = NP // n_data                          # model sub-shards
        per_data = B // n_data
        d_of_b = b_idx // per_data                    # [B,1] data rank
        sub = blk % n_sub                             # [S]
        shard_of = d_of_b * n_sub + sub[None]         # [B, S]
        per_req = -(-nblocks // n_sub)
        NB_loc = per_data * per_req
        local = (b_idx % per_data) * per_req + (blk // n_sub)[None]
        wblk = jnp.where(shard_of[None] == p_idx, local[None], NB_loc)
        return wblk, woff, NB_loc

    # Fallback: round-robin over all shards (correct, not comm-free).
    per_req = -(-nblocks // NP)
    NB_loc = B * per_req
    shard = blk % NP
    wblk_owner = b_idx * per_req + (blk // NP)[None]
    wblk = jnp.where(shard[None, None, :] == p_idx, wblk_owner[None],
                     NB_loc)
    return wblk, woff, NB_loc


def serve_prefill_step(params, cfg: ModelConfig, layout: ServeLayout,
                       tokens, *, block_size: int, NP: int,
                       n_data: Optional[int] = None,
                       embeds=None, capacity_factor: float = 1.25,
                       attn_chunk: int = 1024, layer_constraints=None,
                       seq_parallel: bool = False):
    """Prefill B requests of length S; write KV into a fresh pool.

    Returns (first_tokens [B], pool_k, pool_v [L, NP, NB, bs, K, hd]).
    """
    B, S = (tokens.shape if embeds is None else embeds.shape[:2])
    bs = block_size
    wblk, woff, NB_loc = prefill_layout(B, S, bs, NP, n_data=n_data)
    wblk = wsc(wblk, P(layout.pool_axes, None, None))
    positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    x = embed_tokens(params, cfg, tokens, embeds, positions)
    # Megatron-SP (beyond-paper, seq_parallel=True): keep the residual
    # stream SEQUENCE-sharded over the TP axis between blocks, so the
    # row-parallel all-reduces decompose into reduce-scatter + all-gather
    # (half the bytes) and norms compute 1/tp of the work.
    seq_ax = layout.tp_axis if (seq_parallel and S % 16 == 0) else None
    xspec = P(layout.batch_axes, seq_ax, None)
    x = wsc(x, xspec)
    # Pin the online-softmax carry to heads-over-TP so the chunk scan
    # never reshards it (§Perf-2: 2 all-reduces/chunk/layer otherwise).
    h_ax = layout.tp_axis if cfg.num_heads % 16 == 0 else None
    ba = layout.batch_axes

    def acc_pin(acc):
        """Sharding-pin the online-softmax carry (o, m, l)."""
        o, m, l = acc
        return (wsc(o, P(ba, None, h_ax, None)),
                wsc(m, P(ba, None, h_ax)), wsc(l, P(ba, None, h_ax)))

    core = make_causal_core(cfg, backend="xla", chunk=attn_chunk,
                            acc_constraint=acc_pin)
    K, hd = cfg.num_kv_heads, cfg.head_dim
    dtype = jnp.dtype(cfg.dtype)

    nblocks = S // bs if S % bs == 0 else 0
    n_sub = NP // n_data if n_data else 0
    aligned = (n_data and B % n_data == 0 and S % bs == 0
               and n_sub and nblocks % n_sub == 0)

    def write_pool(k):                               # [B, S, K, hd]
        """Lay a layer's fresh KV into the global pool layout."""
        if aligned:
            # With the data-local layout, the pool IS a reshape of k:
            # pool[d*n_sub+sub, (b%pd)*pr + i] = k[b, (i*n_sub+sub)*bs:..]
            # — zero communication (k is replicated/sharded compatibly),
            # vs the scatter formulation that all-gathered the full
            # [B*S,K,hd] KV per layer (§Perf-2 iteration 3).
            pd, pr = B // n_data, nblocks // n_sub
            k5 = k.reshape(n_data, pd, pr, n_sub, bs, K, hd)
            k6 = jnp.moveaxis(k5, 3, 1)
            # Pin the pre-merge layout (dim0 -> data axes, dim1 -> model
            # sub-shard) so the merge-reshape below is a LOCAL slice, not
            # an all-gather + re-slice.
            if layout.seq_model:
                k6 = wsc(k6, P(layout.pool_axes[:-1], layout.tp_axis))
            else:
                k6 = wsc(k6, P(layout.pool_axes, None))
            pool = k6.reshape(NP, pd * pr, bs, K, hd)
            return wsc(pool, layout.pool_spec())
        pool = jnp.zeros((NP, NB_loc, bs, K, hd), dtype)
        pool = wsc(pool, layout.pool_spec())

        def one(pool_p, wb_p):
            """Per-rank scatter of every token into the local slice."""
            # Scatter all B*S tokens; non-local indices (NB_loc) drop.
            flat_b = wb_p.reshape(-1)
            flat_o = woff.reshape(-1)
            return pool_p.at[flat_b, flat_o].set(
                k.reshape(B * S, K, hd), mode="drop")
        return jax.vmap(one)(pool, wblk)

    def attn_layer(lp, x):
        """One prefill layer's attention over the full chunk."""
        h = apply_norm(lp["ln1"], x, cfg)
        q, k, v = qkv_project(lp["attn"], h, positions, cfg)
        out = core(q, k, v)
        out = out.reshape(B, S, -1).astype(x.dtype) @ lp["attn"]["wo"]
        x = x + wsc(out, xspec)
        return x, (write_pool(k), write_pool(v))

    lc = layer_constraints or {}

    def make_body(moe, name):
        """Scan body factory for the ``moe``/dense layer stack."""
        def body(x, lp):
            """Scanned per-layer prefill step."""
            if name in lc:
                lp = lc[name](lp)
            x, kv = attn_layer(lp, x)
            h = apply_norm(lp["ln2"], x, cfg)
            if moe:
                x = x + apply_moe(lp["moe"], h, cfg, capacity_factor)
            else:
                x = x + apply_ffn(lp["ffn"], h, cfg)
            return wsc(x, xspec), kv
        return body

    if cfg.family == "dense":
        x, (pk, pv) = jax.lax.scan(make_body(False, "layers"), x,
                                   params["layers"])
    elif cfg.family == "moe":
        nd = cfg.first_k_dense
        if nd:
            x, (pkd, pvd) = jax.lax.scan(make_body(False, "dense_layers"),
                                         x, params["dense_layers"])
        x, (pkm, pvm) = jax.lax.scan(make_body(True, "moe_layers"), x,
                                     params["moe_layers"])
        pk = jnp.concatenate([pkd, pkm], 0) if nd else pkm
        pv = jnp.concatenate([pvd, pvm], 0) if nd else pvm
    else:
        raise ValueError("pooled prefill is for attention archs")

    logits = unembed(params, cfg, x[:, -1])
    return jnp.argmax(logits, -1).astype(jnp.int32), pk, pv


# --------------------------------------------------------------------- #
# Prefill for hybrid / ssm archs: forward + recurrent states (+ window)
# --------------------------------------------------------------------- #
def serve_prefill_step_state(params, cfg: ModelConfig, layout: ServeLayout,
                             tokens, *, max_len: int, embeds=None):
    """Returns (first_tokens [B], DecodeState) — the O(1)/windowed state
    these families decode from (no cluster KV pool involved)."""
    from repro.models.prefill import prefill
    logits, state = prefill(params, cfg, tokens, embeds, max_len=max_len)
    return jnp.argmax(logits, -1).astype(jnp.int32), state


# --------------------------------------------------------------------- #
# Stateful decode for hybrid / ssm archs (no KV pool to shard)
# --------------------------------------------------------------------- #
def serve_decode_step_state(params, cfg: ModelConfig, layout: ServeLayout,
                            state, tokens):
    """Hybrid/SSM decode: O(1)-state recurrence, batch over data axis.

    DistAttention is inapplicable (DESIGN.md §Arch-applicability); the
    local-attention window cache for hybrid archs rides in ``state``.
    """
    from repro.models.model import decode_step
    logits, new_state = decode_step(params, cfg, state, tokens)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    return nxt, new_state


# --------------------------------------------------------------------- #
# Global-pool steps: one [ranks, L, NB, bs, K, hd] tensor for the whole
# cluster (``serving.globalpool.GlobalKVPool``). Same paged math as the
# in-process engine steps (models/prefill.py), but every rank's pool is
# a slice of ONE array: vmapped over the rank axis on a single device,
# shard_mapped with collective LSE-merges (paper Eq. 3) under a mesh.
# --------------------------------------------------------------------- #
def _shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map (0.5+, check_vma) or the experimental module
    (0.4.x, check_rep) — whichever this jax provides."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


# Incremented once per trace of a global-pool jit; serving tests assert
# compiles stay bounded by (table bucket, rank count), never context.
_GLOBAL_TRACE_COUNT = 0


def global_trace_count() -> int:
    """Times a global-pool step retraced (tests bound this)."""
    return _GLOBAL_TRACE_COUNT


def _shard_rank_base(mesh, pool_axes, r_loc):
    """First global rank owned by the calling shard (inside shard_map)."""
    idx = jnp.int32(0)
    for ax in pool_axes:
        idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
    return idx * r_loc


def _global_write_decode(g, rows, wblk, woff, *, rank, mesh, pool_axes):
    """Deferred decode tail-append: all L layers' new token in ONE
    scatter. rows [L, B, K, hd]; wblk/woff [B] (sentinel NB drops)."""
    val = jnp.swapaxes(rows, 0, 1).astype(g.dtype)        # [B, L, K, hd]
    if mesh is None:
        return g.at[rank, :, wblk, woff].set(val, mode="drop")

    def shard(gs, vs):
        lr = rank - _shard_rank_base(mesh, pool_axes, gs.shape[0])
        # lr lands outside [0, R_loc) on every shard but the owner's.
        # A NEGATIVE index would WRAP (JAX indexing), so remap it to
        # R_loc — genuinely out of bounds — and let mode="drop" skip it.
        lr = jnp.where((lr >= 0) & (lr < gs.shape[0]), lr, gs.shape[0])
        return gs.at[lr, :, wblk, woff].set(vs, mode="drop")

    return _shard_map(shard, mesh, in_specs=(P(pool_axes), P()),
                      out_specs=P(pool_axes))(g, val)


def _global_write_prefill(g, rows, wrank, wblk, woff, *, mesh, pool_axes):
    """Deferred prefill-chunk append: row c of the chunk lands in rank
    wrank[c] (any rank — owner OR creditor: the striped ``PrefixSink``
    write is now just more rows of this one scatter). rows [L, C, K, hd];
    wrank/wblk/woff [C] (block sentinel NB drops padding rows)."""
    val = jnp.swapaxes(rows, 0, 1).astype(g.dtype)        # [C, L, K, hd]
    if mesh is None:
        return g.at[wrank, :, wblk, woff].set(val, mode="drop")

    def shard(gs, vs):
        lr = wrank - _shard_rank_base(mesh, pool_axes, gs.shape[0])
        # Remap foreign ranks (negative lr would wrap, see above).
        lr = jnp.where((lr >= 0) & (lr < gs.shape[0]), lr, gs.shape[0])
        return gs.at[lr, :, wblk, woff].set(vs, mode="drop")

    return _shard_map(shard, mesh, in_specs=(P(pool_axes), P()),
                      out_specs=P(pool_axes))(g, val)


def _global_pooled_decode(q1, gk_l, gv_l, tables, tails, scale, *,
                          mesh, pool_axes, backend):
    """Merged pooled partial for one layer. q1 [B,H,hd] broadcast;
    gk_l/gv_l [NR,NB,bs,K,hd]; tables [NR,B,MB]; tails [NR,B]."""
    from repro.kernels.ops import paged_micro_attention_ranks
    if mesh is None:
        o, m, l = paged_micro_attention_ranks(q1, gk_l, gv_l, tables,
                                              tails, scale=scale,
                                              backend=backend)
        return merge_partials(o, m, l, axis=0)

    def shard(qs, pk, pv, tb, tl):
        o, m, l = paged_micro_attention_ranks(qs, pk, pv, tb, tl,
                                              scale=scale, backend=backend)
        o, m, l = merge_partials(o, m, l, axis=0)     # local ranks
        return merge_partials_collective(o, m, l, pool_axes)

    return _shard_map(shard, mesh,
                      in_specs=(P(), P(pool_axes), P(pool_axes),
                                P(pool_axes), P(pool_axes)),
                      out_specs=(P(), P(), P()))(q1, gk_l, gv_l,
                                                 tables, tails)


def _global_pooled_prefill(qc, gk_l, gv_l, tables, tails, scale, *,
                           mesh, pool_axes, backend):
    """Merged prefix partial for one prefill chunk. qc [C,H,hd];
    tables [NR,MB]; tails [NR]."""
    from repro.kernels.ops import paged_prefill_attention_ranks
    if mesh is None:
        o, m, l = paged_prefill_attention_ranks(qc, gk_l, gv_l, tables,
                                                tails, scale=scale,
                                                backend=backend)
        return merge_partials(o, m, l, axis=0)

    def shard(qs, pk, pv, tb, tl):
        o, m, l = paged_prefill_attention_ranks(qs, pk, pv, tb, tl,
                                                scale=scale,
                                                backend=backend)
        o, m, l = merge_partials(o, m, l, axis=0)
        return merge_partials_collective(o, m, l, pool_axes)

    return _shard_map(shard, mesh,
                      in_specs=(P(), P(pool_axes), P(pool_axes),
                                P(pool_axes), P(pool_axes)),
                      out_specs=(P(), P(), P()))(qc, gk_l, gv_l,
                                                 tables, tails)


def _scan_layers_global(params, cfg, x, make_body):
    """Layer scan with (lp, layer_index) xs — the global pool stays a
    closed-over READ-ONLY array (no per-layer pool carry copies)."""
    L = cfg.num_layers
    if cfg.family == "dense":
        return jax.lax.scan(make_body(False), x,
                            (params["layers"],
                             jnp.arange(L, dtype=jnp.int32)))
    nd = cfg.first_k_dense
    ys_d = None
    if nd:
        x, ys_d = jax.lax.scan(make_body(False), x,
                               (params["dense_layers"],
                                jnp.arange(nd, dtype=jnp.int32)))
    x, ys_m = jax.lax.scan(make_body(True), x,
                           (params["moe_layers"],
                            jnp.arange(nd, L, dtype=jnp.int32)))
    if nd:
        ys_m = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                            ys_d, ys_m)
    return x, ys_m


@functools.partial(jax.jit,
                   static_argnames=("cfg", "backend", "mesh", "pool_axes",
                                    "rank"),
                   donate_argnames=("gk", "gv"))
def _decode_step_global_jit(params, tokens, lens, gk, gv, tables, tails,
                            wblk, woff, *, cfg, backend, mesh, pool_axes,
                            rank):
    global _GLOBAL_TRACE_COUNT
    _GLOBAL_TRACE_COUNT += 1
    B = tokens.shape[0]
    scale = cfg.head_dim ** -0.5
    x = embed_tokens(params, cfg, tokens[:, None], None,
                     positions=lens[:, None])

    def make_body(moe):
        def body(x, xs):
            lp, li = xs
            h = apply_norm(lp["ln1"], x, cfg)
            q, k, v = qkv_project(lp["attn"], h, lens[:, None], cfg)
            gk_l = jax.lax.dynamic_index_in_dim(gk, li, axis=1,
                                                keepdims=False)
            gv_l = jax.lax.dynamic_index_in_dim(gv, li, axis=1,
                                                keepdims=False)
            pooled = _global_pooled_decode(q[:, 0], gk_l, gv_l, tables,
                                           tails, scale, mesh=mesh,
                                           pool_axes=pool_axes,
                                           backend=backend)
            # §Perf-1 schedule: the pool rides read-only; the new token
            # joins as an explicit self partial (tables/tails passed in
            # EXCLUDE it) and its KV is written after the scan.
            self_part = micro_attention_decode(
                q[:, 0], k, v, jnp.ones((B, 1), bool), scale=scale)
            o, m, l = combine(pooled, self_part)
            out = finalize(o, l)
            out = out.reshape(B, 1, -1).astype(x.dtype) @ lp["attn"]["wo"]
            x = x + out
            h = apply_norm(lp["ln2"], x, cfg)
            if moe:
                x = x + apply_moe(lp["moe"], h, cfg, capacity_factor=-1.0)
            else:
                x = x + apply_ffn(lp["ffn"], h, cfg)
            return x, (k[:, 0], v[:, 0])
        return body

    x, (ks, vs) = _scan_layers_global(params, cfg, x, make_body)
    gk = _global_write_decode(gk, ks, wblk, woff, rank=rank, mesh=mesh,
                              pool_axes=pool_axes)
    gv = _global_write_decode(gv, vs, wblk, woff, rank=rank, mesh=mesh,
                              pool_axes=pool_axes)
    logits = unembed(params, cfg, x[:, 0])
    return logits, gk, gv


def decode_step_global(params, cfg: ModelConfig, tokens, lens, gk, gv,
                       tables, tails, wblk, woff, *, rank: int, mesh=None,
                       pool_axes: Tuple[str, ...] = ("data",),
                       backend: Optional[str] = None):
    """Paged DistAttention decode over the GLOBAL pool tensor.

    tokens/lens: [B]; gk/gv: [NR, L, NB, bs, K, hd] — the whole
    cluster's KV, DONATED (continue with the returned arrays);
    tables/tails: [NR, B, MB] / [NR, B] from ``build_local_tables`` over
    ``GlobalKVPool.ranks``, POST-EDITED so the pending token's slot is
    excluded (it enters as a self partial); wblk/woff: [B] tail target
    in rank ``rank``'s slice (sentinel NB drops); ``rank``: the calling
    engine's rank (static — there are only NR of them). With ``mesh``,
    the rank axis shards over ``pool_axes`` and each shard computes its
    partial under shard_map; partials LSE-merge with pmax/psum (Eq. 3).
    Queries broadcast; KV never moves. Returns (logits, gk, gv).
    """
    assert cfg.family in ("dense", "moe"), "only attention archs pool KV"
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    return _decode_step_global_jit(
        params, jnp.asarray(tokens, jnp.int32),
        jnp.asarray(lens, jnp.int32), gk, gv,
        jnp.asarray(tables, jnp.int32), jnp.asarray(tails, jnp.int32),
        jnp.asarray(wblk, jnp.int32), jnp.asarray(woff, jnp.int32),
        cfg=cfg, backend=backend, mesh=mesh,
        pool_axes=tuple(pool_axes), rank=rank)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "backend", "mesh", "pool_axes"),
                   donate_argnames=("gk", "gv"))
def _prefill_chunk_global_jit(params, tokens, positions, valid, last_idx,
                              gk, gv, tables, tails, wrank, wblk, woff,
                              *, cfg, backend, mesh, pool_axes):
    global _GLOBAL_TRACE_COUNT
    _GLOBAL_TRACE_COUNT += 1
    scale = cfg.head_dim ** -0.5
    x = embed_tokens(params, cfg, tokens, None, positions)
    B, C = x.shape[:2]

    def make_body(moe):
        def body(x, xs):
            lp, li = xs
            h = apply_norm(lp["ln1"], x, cfg)
            q, k, v = qkv_project(lp["attn"], h, positions, cfg)
            gk_l = jax.lax.dynamic_index_in_dim(gk, li, axis=1,
                                                keepdims=False)
            gv_l = jax.lax.dynamic_index_in_dim(gv, li, axis=1,
                                                keepdims=False)
            # Prefix partial over the written tokens [0, t0) on EVERY
            # rank (tables mask this chunk's rows out), + chunk-causal.
            part = _global_pooled_prefill(q[0], gk_l, gv_l, tables,
                                          tails, scale, mesh=mesh,
                                          pool_axes=pool_axes,
                                          backend=backend)
            o_c, m_c, l_c = micro_attention_prefill(q, k, v, positions,
                                                    positions, valid)
            part = combine(part, (o_c[0], m_c[0], l_c[0]))
            out = finalize(part[0], part[2])
            out = out.reshape(B, C, -1).astype(x.dtype) @ lp["attn"]["wo"]
            x = x + out
            h = apply_norm(lp["ln2"], x, cfg)
            if moe:
                x = x + apply_moe(lp["moe"], h, cfg, capacity_factor=-1.0)
            else:
                x = x + apply_ffn(lp["ffn"], h, cfg)
            return x, (k[0], v[0])
        return body

    x, (ks, vs) = _scan_layers_global(params, cfg, x, make_body)
    gk = _global_write_prefill(gk, ks, wrank, wblk, woff, mesh=mesh,
                               pool_axes=pool_axes)
    gv = _global_write_prefill(gv, vs, wrank, wblk, woff, mesh=mesh,
                               pool_axes=pool_axes)
    logits = unembed(params, cfg, jnp.take(x, last_idx, axis=1))
    return logits, gk, gv, ks, vs


def prefill_chunk_global(params, cfg: ModelConfig, tokens, t0: int,
                         n_valid: int, gk, gv, tables, tails, wrank,
                         wblk, woff, *, mesh=None,
                         pool_axes: Tuple[str, ...] = ("data",),
                         backend: Optional[str] = None):
    """Streaming-prefill chunk [t0, t0+C) over the GLOBAL pool tensor.

    Same contract as ``prefill_chunk_paged`` except the pool is the
    whole cluster's [NR, L, NB, bs, K, hd] (DONATED) and the chunk's
    rows can land on ANY rank: wrank/wblk/woff [C] give each row's
    (rank, block, offset) — creditor-striped rows (``PrefixSink``) are
    just rows with a creditor wrank, written by the SAME deferred
    scatter as owner rows (remote DMA under GSPMD when a mesh is
    attached). tables/tails: [NR, MB] / [NR] addressing the written
    prefix [0, t0) on every rank. Returns (logits [1, V], gk, gv,
    k_chunk [L, C, K, hd], v_chunk).
    """
    assert cfg.family in ("dense", "moe"), "only attention archs pool KV"
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    C = len(tokens)
    positions = t0 + jnp.arange(C, dtype=jnp.int32)[None]
    valid = (jnp.arange(C, dtype=jnp.int32) < n_valid)[None]
    return _prefill_chunk_global_jit(
        params, jnp.asarray(tokens, jnp.int32)[None], positions, valid,
        jnp.asarray(n_valid - 1, jnp.int32), gk, gv,
        jnp.asarray(tables, jnp.int32), jnp.asarray(tails, jnp.int32),
        jnp.asarray(wrank, jnp.int32), jnp.asarray(wblk, jnp.int32),
        jnp.asarray(woff, jnp.int32), cfg=cfg, backend=backend,
        mesh=mesh, pool_axes=tuple(pool_axes))
