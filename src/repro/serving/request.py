"""Request lifecycle objects for the serving engine and cluster runtime.

Lifecycle: WAITING -> PREFILLING -> RUNNING -> FINISHED, with FAILED
(pool exhaustion / infeasible placement) and CANCELLED (caller-initiated
via ``RequestHandle.cancel``) as terminal branches. Cancellation is
cooperative inside an in-flight streaming prefill: the engine checks
``Request.cancelled`` between chunks and rolls the admission back via
the all-or-nothing reservation machinery.

PAUSED is the one non-terminal detour: under overload the ``Preemptor``
stops a RUNNING request at a step boundary, spills its KV chain to the
host tier, and parks it (prompt/output/stream state intact, device
state fully released). A paused request later resumes RUNNING with
byte-identical KV, or is cancelled while parked. ``pause_requested``
mirrors ``cancelled`` for the cooperative mid-prefill case: the engine
aborts the admission with the same exact-rollback discipline but keeps
the request WAITING instead of making it terminal.

Request ids are allocated PER SERVER (``RequestIdAllocator``): two
``LLMServer``/``Cluster`` instances in one process each get a dense,
deterministic 0..N id space instead of sharing one module-global
counter whose values drift with test/import order. Constructing a bare
``Request`` without a server still works — it falls back to a private
module counter — but anything submitted through a server gets the
server's ids.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

# Fallback for standalone Request() construction only; servers allocate
# from their own RequestIdAllocator.
_fallback_counter = itertools.count()


class RequestIdAllocator:
    """Dense per-server request-id space (deterministic across runs)."""

    def __init__(self, start: int = 0):
        self._counter = itertools.count(start)

    def next_id(self) -> int:
        """Return the next dense request id."""
        return next(self._counter)


class RequestState(enum.Enum):
    """Lifecycle states (see module docstring for the transition map)."""

    WAITING = "waiting"
    PREFILLING = "prefilling"
    RUNNING = "running"
    PAUSED = "paused"          # preempted: KV spilled to host, resumable
    FINISHED = "finished"
    FAILED = "failed"
    CANCELLED = "cancelled"


@dataclass
class SamplingParams:
    """Per-request decoding knobs (greedy when ``temperature <= 0``)."""

    max_new_tokens: int = 64
    temperature: float = 0.0          # 0 => greedy
    eos_token: Optional[int] = None
    # Any of these tokens terminates generation (the token IS emitted,
    # like eos_token — callers strip it if they don't want it).
    stop_tokens: Tuple[int, ...] = ()
    # Keep only the k highest logits before sampling (0 => disabled).
    # Greedy (temperature <= 0) is unaffected.
    top_k: int = 0
    seed: int = 0


@dataclass
class Request:
    """One in-flight generation: prompt, lifecycle state, placement.

    Mutable by design — the engine, scheduler, preemptor, and frontend
    all annotate it. ``spans`` is the cluster-wide KV placement map;
    the preemption fields record pause/resume history for the
    anti-thrash cap and the SLO victim ranking.
    """

    prompt: List[int]
    sampling: SamplingParams = field(default_factory=SamplingParams)
    req_id: int = field(default_factory=lambda: next(_fallback_counter))
    state: RequestState = RequestState.WAITING
    output: List[int] = field(default_factory=list)
    # --- lifecycle timestamps (time.monotonic domain) ------------------ #
    arrival_time: float = 0.0         # set at server/cluster submit
    finish_time: Optional[float] = None
    token_times: List[float] = field(default_factory=list)  # per emit
    # --- frontend scheduling ------------------------------------------- #
    priority: int = 0                 # higher = scheduled first
    deadline_s: Optional[float] = None  # SLO, seconds after arrival
    cancelled: bool = False           # cooperative-cancel flag
    # --- preemption (overload survival) -------------------------------- #
    pause_requested: bool = False     # cooperative mid-prefill pause flag
    preemptions: int = 0              # times this request has been paused
    paused_at: Optional[float] = None  # monotonic time of the last pause
    # --- fault recovery (token replay) ---------------------------------- #
    needs_replay: bool = False        # re-admit via prompt+output re-prefill
    replays: int = 0                  # completed token-replay recoveries
    replayed_tokens: int = 0          # generated tokens re-prefilled so far
    slot: Optional[int] = None        # engine batch slot while RUNNING
    # Cluster placement: ordered spans (instance_id, n_tokens) covering
    # [0, len); the LAST span is always on the owner (debtor) instance.
    spans: List[Tuple[int, int]] = field(default_factory=list)

    @property
    def length(self) -> int:
        """Total tokens (prompt + emitted output)."""
        return len(self.prompt) + len(self.output)

    @property
    def done(self) -> bool:
        """True once the request reached a terminal state."""
        return self.state in (RequestState.FINISHED, RequestState.FAILED,
                              RequestState.CANCELLED)

    @property
    def deadline_at(self) -> Optional[float]:
        """Absolute deadline in the arrival_time clock domain."""
        if self.deadline_s is None:
            return None
        return self.arrival_time + self.deadline_s

    def urgency(self, now: float) -> float:
        """Scheduling key: higher = serve/offload first.

        Priority STRICTLY dominates: the deadline term lives in
        (0, 0.5], so no deadline pressure can lift a request past the
        next integer priority level. Within a priority level a request
        gets more urgent as its deadline approaches, saturating at
        +0.5 once the deadline is reached (an expired request stays the
        most urgent of its own level, never of a higher one). Requests
        without a deadline tie at their bare priority.
        """
        u = float(self.priority)
        dl = self.deadline_at
        if dl is not None:
            u += 1.0 / (2.0 + max(dl - now, 0.0))
        return u
