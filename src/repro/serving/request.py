"""Request lifecycle objects for the serving engine and cluster runtime."""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

_req_counter = itertools.count()


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"
    RUNNING = "running"
    FINISHED = "finished"
    FAILED = "failed"


@dataclass
class SamplingParams:
    max_new_tokens: int = 64
    temperature: float = 0.0          # 0 => greedy
    eos_token: Optional[int] = None
    seed: int = 0


@dataclass
class Request:
    prompt: List[int]
    sampling: SamplingParams = field(default_factory=SamplingParams)
    req_id: int = field(default_factory=lambda: next(_req_counter))
    state: RequestState = RequestState.WAITING
    output: List[int] = field(default_factory=list)
    arrival_time: float = 0.0
    finish_time: Optional[float] = None
    slot: Optional[int] = None        # engine batch slot while RUNNING
    # Cluster placement: ordered spans (instance_id, n_tokens) covering
    # [0, len); the LAST span is always on the owner (debtor) instance.
    spans: List[Tuple[int, int]] = field(default_factory=list)

    @property
    def length(self) -> int:
        return len(self.prompt) + len(self.output)

    @property
    def done(self) -> bool:
        return self.state in (RequestState.FINISHED, RequestState.FAILED)
