"""gManager: centralized global planner (paper §6.1-6.2).

Maintains the request placement map from periodic rManager heartbeats
(delta-encoded; full on gManager failover), detects dead instances via
heartbeat timeouts, runs Algorithm 1 periodically, and emits MoveKVCache
instructions. The map is deliberately allowed to go stale — safety comes
from the try_move reservation on the destination (paper Fig. 8 step 4-5).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.serving.perfmodel import InstancePerfModel
from repro.serving.protocol import (Heartbeat, MoveKVCache,
                                    RequestPlacementEntry)
from repro.serving.scheduler import GreedyScheduler, InstanceView


@dataclass
class _InstanceStatus:
    inst_id: int
    last_seq: int = 0
    last_beat: float = 0.0
    batch_size: int = 0
    mem_blocks_total: int = 0
    mem_blocks_used: int = 0
    alive: bool = True
    # req_id -> entry (this instance's slice of the request)
    entries: Dict[int, RequestPlacementEntry] = field(default_factory=dict)


class GManager:
    def __init__(self, perf: InstancePerfModel, block_size: int,
                 heartbeat_timeout: float = 3.0,
                 beta_thres: int = 64, mem_util_thres: float = 0.8):
        self.scheduler = GreedyScheduler(perf, block_size,
                                         beta_thres=beta_thres,
                                         mem_util_thres=mem_util_thres)
        self.block_size = block_size
        self.timeout = heartbeat_timeout
        self.instances: Dict[int, _InstanceStatus] = {}
        self.bootstrapping = True     # new gManager needs full heartbeats

    # --- heartbeat ingestion ------------------------------------------ #
    def on_heartbeat(self, hb: Heartbeat, now: Optional[float] = None
                     ) -> bool:
        """Returns False if a FULL heartbeat is required (failover resync
        or out-of-order delta)."""
        now = time.monotonic() if now is None else now
        st = self.instances.get(hb.inst_id)
        if st is None:
            st = _InstanceStatus(hb.inst_id)
            self.instances[hb.inst_id] = st
            if not hb.full:
                return False                      # need full state first
        if not hb.full and hb.seq != st.last_seq + 1:
            return False                          # lost a delta -> resync
        if hb.full:
            st.entries = {}
        for e in hb.entries:
            st.entries[e.req_id] = e
        for rid in hb.removed_req_ids:
            st.entries.pop(rid, None)
        st.last_seq = hb.seq
        st.last_beat = now
        st.batch_size = hb.batch_size
        st.mem_blocks_total = hb.mem_blocks_total
        st.mem_blocks_used = hb.mem_blocks_used
        st.alive = True
        return True

    # --- failure detection / elasticity -------------------------------- #
    def check_liveness(self, now: Optional[float] = None) -> List[int]:
        """Mark instances dead on heartbeat timeout; return newly dead."""
        now = time.monotonic() if now is None else now
        dead = []
        for st in self.instances.values():
            if st.alive and now - st.last_beat > self.timeout:
                st.alive = False
                dead.append(st.inst_id)
        return dead

    def deregister(self, inst_id: int) -> None:
        self.instances.pop(inst_id, None)

    def requests_touching(self, inst_id: int) -> List[int]:
        st = self.instances.get(inst_id)
        return sorted(st.entries) if st else []

    def owner_of(self, req_id: int) -> Optional[int]:
        for st in self.instances.values():
            e = st.entries.get(req_id)
            if e is not None and e.local:
                return st.inst_id
        return None

    # --- planning ------------------------------------------------------ #
    def _views(self) -> List[InstanceView]:
        views = []
        for st in self.instances.values():
            reqs = {}
            for rid, e in st.entries.items():
                # total length is only known to the owner; approximate by
                # this instance's share (the scheduler only needs owned
                # lengths, where local=True gives the true tail holder).
                reqs[rid] = (e.num_blocks * self.block_size,
                             e.num_blocks, e.local)
            hosted = sum(e.num_blocks for e in st.entries.values()
                         if not e.local) * self.block_size
            views.append(InstanceView(
                inst_id=st.inst_id, batch_size=st.batch_size,
                mem_blocks_total=st.mem_blocks_total,
                mem_blocks_used=st.mem_blocks_used,
                requests=reqs, hosted_tokens=hosted, alive=st.alive))
        return views

    def plan_moves(self) -> List[MoveKVCache]:
        moves = self.scheduler.plan(self._views())
        return [MoveKVCache(m.req_id, m.num_blocks, m.src, m.dst)
                for m in moves]

    # --- placement queries for new requests ----------------------------- #
    def pick_instance_for_new_request(self) -> Optional[int]:
        """Paper policy: dispatch to the instance with most free memory."""
        alive = [s for s in self.instances.values() if s.alive]
        if not alive:
            return None
        return max(alive, key=lambda s: s.mem_blocks_total -
                   s.mem_blocks_used).inst_id
