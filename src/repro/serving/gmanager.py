"""gManager: centralized global planner (paper §6.1-6.2).

Maintains the request placement map from periodic rManager heartbeats
(delta-encoded; full on gManager failover), detects dead instances via
heartbeat timeouts, runs Algorithm 1 periodically, and emits MoveKVCache
instructions. The map is deliberately allowed to go stale — safety comes
from the try_move reservation on the destination (paper Fig. 8 step 4-5).

Striped-plan protocol: since the multi-creditor generalization each
``MoveKVCache`` carries a LIST of legs (destination, whole blocks) for
one source request — ``plan_moves`` translates the scheduler's
``StripedMove``s one-to-one. The per-request placement map is
cross-referenced when building scheduler views: every owner view gets
``req_spans`` (req_id -> {creditor: blocks}, rebuilt fresh from the
heartbeat entries each planning round), and because the scheduler plans
against COPIES, ``_views`` stays consistent with the heartbeat state no
matter how many times planning runs between beats.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.serving.perfmodel import InstancePerfModel
from repro.serving.protocol import (Heartbeat, MoveKVCache, MoveLeg,
                                    RequestPlacementEntry)
from repro.serving.scheduler import GreedyScheduler, InstanceView


class ArrivalEstimator:
    """EWMA estimator of the live arrival stream (paper §6.2's online
    "average length of new requests", generalized with a rate term).

    ``observe(now, n_tokens)`` folds one arrival in: ``n_tokens`` is
    the request's expected KV footprint (prompt + max_new_tokens — the
    worst case the pool must plan for) and ``now`` feeds an EWMA of the
    inter-arrival gap. The length estimate starts at the static
    ``avg_new_req_len`` config prior and converges to the traffic; the
    rate is 0 ("unknown") until two arrivals have been seen. The
    gManager pushes both into ``GreedyScheduler`` before each planning
    round, replacing the static knob in Algorithm 1's batch-growth
    credit."""

    def __init__(self, alpha: float = 0.3, init_len: int = 512):
        assert 0.0 < alpha <= 1.0
        self.alpha = alpha
        self._avg_len = float(init_len)
        self._avg_gap: Optional[float] = None
        self._last_t: Optional[float] = None
        self.samples = 0

    def observe(self, now: float, n_tokens: int) -> None:
        """Fold one arrival (at monotonic ``now``, ``n_tokens`` of
        expected KV footprint) into the EWMA state."""
        a = self.alpha
        self._avg_len += a * (float(n_tokens) - self._avg_len)
        if self._last_t is not None:
            gap = max(1e-6, now - self._last_t)
            self._avg_gap = gap if self._avg_gap is None else \
                self._avg_gap + a * (gap - self._avg_gap)
        self._last_t = now
        self.samples += 1

    @property
    def avg_new_req_len(self) -> int:
        """Current length estimate (tokens), floored at one."""
        return max(1, int(round(self._avg_len)))

    @property
    def rate_hz(self) -> float:
        """EWMA arrival rate in req/s (0.0 until two arrivals seen)."""
        if self._avg_gap is None:
            return 0.0
        return 1.0 / self._avg_gap


@dataclass
class _InstanceStatus:
    inst_id: int
    last_seq: int = 0
    last_beat: float = 0.0
    batch_size: int = 0
    mem_blocks_total: int = 0
    mem_blocks_used: int = 0
    cache_blocks: int = 0          # unpinned (reclaimable) cache replicas
    alive: bool = True
    missed_beats: int = 0          # consecutive silent cluster steps
    # req_id -> entry (this instance's slice of the request)
    entries: Dict[int, RequestPlacementEntry] = field(default_factory=dict)


class GManager:
    """Centralized planner: heartbeat map + Algorithm 1 + placement.

    Owns the ``GreedyScheduler`` (and feeds it the live
    ``ArrivalEstimator`` state before every planning round), detects
    dead instances, and answers placement queries for new arrivals."""

    def __init__(self, perf: InstancePerfModel, block_size: int,
                 heartbeat_timeout: float = 3.0,
                 beta_thres: int = 64, mem_util_thres: float = 0.8,
                 avg_new_req_len: int = 512, max_stripes: int = 8,
                 reclaim_horizon_s: float = 1.0,
                 arrival_alpha: float = 0.3,
                 heartbeat_timeout_steps: int = 0):
        self.scheduler = GreedyScheduler(perf, block_size,
                                         beta_thres=beta_thres,
                                         mem_util_thres=mem_util_thres,
                                         avg_new_req_len=avg_new_req_len,
                                         max_stripes=max_stripes,
                                         reclaim_horizon_s=reclaim_horizon_s)
        self.block_size = block_size
        self.timeout = heartbeat_timeout
        self.timeout_steps = heartbeat_timeout_steps  # 0 = step check off
        self.instances: Dict[int, _InstanceStatus] = {}
        self.bootstrapping = True     # new gManager needs full heartbeats
        self.arrivals = ArrivalEstimator(alpha=arrival_alpha,
                                         init_len=avg_new_req_len)

    # --- arrival stream ------------------------------------------------ #
    def observe_arrival(self, now: float, n_tokens: int) -> None:
        """Feed one frontend arrival (expected KV footprint in tokens)
        into the EWMA estimator; the next ``plan_moves`` round plans
        with the updated ``avg_new_req_len``/rate instead of the static
        config knob."""
        self.arrivals.observe(now, n_tokens)

    # --- heartbeat ingestion ------------------------------------------ #
    def on_heartbeat(self, hb: Heartbeat, now: Optional[float] = None
                     ) -> bool:
        """Returns False if a FULL heartbeat is required (failover resync
        or out-of-order delta)."""
        now = time.monotonic() if now is None else now
        st = self.instances.get(hb.inst_id)
        if st is None:
            st = _InstanceStatus(hb.inst_id)
            self.instances[hb.inst_id] = st
            if not hb.full:
                return False                      # need full state first
        if not hb.full and hb.seq != st.last_seq + 1:
            return False                          # lost a delta -> resync
        if hb.full:
            st.entries = {}
        for e in hb.entries:
            st.entries[e.req_id] = e
        for rid in hb.removed_req_ids:
            st.entries.pop(rid, None)
        st.last_seq = hb.seq
        st.last_beat = now
        st.batch_size = hb.batch_size
        st.mem_blocks_total = hb.mem_blocks_total
        st.mem_blocks_used = hb.mem_blocks_used
        st.cache_blocks = hb.cache_blocks
        st.alive = True
        st.missed_beats = 0
        return True

    # --- failure detection / elasticity -------------------------------- #
    def check_liveness(self, now: Optional[float] = None) -> List[int]:
        """Mark instances dead on heartbeat timeout; return newly dead."""
        now = time.monotonic() if now is None else now
        dead = []
        for st in self.instances.values():
            if st.alive and now - st.last_beat > self.timeout:
                st.alive = False
                dead.append(st.inst_id)
        return dead

    def check_liveness_steps(self, beat_insts) -> List[int]:
        """Step-count liveness: every alive instance NOT in
        ``beat_insts`` (the set that heartbeat this cluster step) gets
        one missed beat; ``heartbeat_timeout_steps`` consecutive misses
        mark it dead. Deterministic companion to the wall-clock
        ``check_liveness`` — a single beat resets the counter, so a
        silence gap shorter than the timeout is tolerated. Returns the
        newly dead instance ids (empty when the step check is off)."""
        if self.timeout_steps <= 0:
            return []
        dead = []
        for st in self.instances.values():
            if not st.alive:
                continue
            if st.inst_id in beat_insts:
                continue
            st.missed_beats += 1
            if st.missed_beats >= self.timeout_steps:
                st.alive = False
                dead.append(st.inst_id)
        return dead

    def deregister(self, inst_id: int) -> None:
        """Forget a (dead or drained) instance entirely."""
        self.instances.pop(inst_id, None)

    def requests_touching(self, inst_id: int) -> List[int]:
        """Request ids with any KV (local or hosted) on ``inst_id``."""
        st = self.instances.get(inst_id)
        return sorted(st.entries) if st else []

    def owner_of(self, req_id: int) -> Optional[int]:
        """Instance id owning ``req_id``'s local span, if any."""
        for st in self.instances.values():
            e = st.entries.get(req_id)
            if e is not None and e.local:
                return st.inst_id
        return None

    # --- planning ------------------------------------------------------ #
    def _views(self) -> List[InstanceView]:
        # Cross-instance placement: req_id -> {creditor_inst: blocks}
        # (every non-local slice), and req_id -> total blocks anywhere.
        spans: Dict[int, Dict[int, int]] = {}
        total_blocks: Dict[int, int] = {}
        for st in self.instances.values():
            for rid, e in st.entries.items():
                total_blocks[rid] = total_blocks.get(rid, 0) + e.num_blocks
                if not e.local:
                    spans.setdefault(rid, {})[st.inst_id] = e.num_blocks
        views = []
        for st in self.instances.values():
            reqs = {}
            off = 0
            req_spans: Dict[int, Dict[int, int]] = {}
            for rid, e in st.entries.items():
                # The owner sees the request's TRUE total length (its
                # local slice plus every creditor span); a creditor only
                # sees its own slice.
                n = total_blocks[rid] if e.local else e.num_blocks
                reqs[rid] = (n * self.block_size, e.num_blocks, e.local)
                if e.local and rid in spans:
                    req_spans[rid] = dict(spans[rid])
                    off += sum(spans[rid].values()) * self.block_size
            hosted = sum(e.num_blocks for e in st.entries.values()
                         if not e.local) * self.block_size
            views.append(InstanceView(
                inst_id=st.inst_id, batch_size=st.batch_size,
                mem_blocks_total=st.mem_blocks_total,
                mem_blocks_used=st.mem_blocks_used,
                requests=reqs, offloaded_tokens=off,
                hosted_tokens=hosted, alive=st.alive,
                req_spans=req_spans, cache_blocks=st.cache_blocks))
        return views

    def plan_moves(self, urgency: Optional[Dict[int, float]] = None
                   ) -> List[MoveKVCache]:
        """Run Algorithm 1 against the current heartbeat views.

        ``urgency`` (req_id -> score, from the serving frontend's
        priority/deadline lifecycle) biases the planner: higher-urgency
        requests are picked for memory relief first.
        """
        # Push the live arrival estimate into Algorithm 1: the
        # batch-growth credit plans with observed traffic, not the
        # static config prior.
        if self.arrivals.samples > 0:
            self.scheduler.avg_new_len = self.arrivals.avg_new_req_len
        self.scheduler.arrival_rate_hz = self.arrivals.rate_hz
        moves = self.scheduler.plan(self._views(), urgency=urgency)
        return [MoveKVCache(m.req_id, m.src,
                            [MoveLeg(leg.dst, leg.num_blocks)
                             for leg in m.legs], kind=m.kind)
                for m in moves]

    # --- placement queries for new requests ----------------------------- #
    def pick_instance_for_new_request(self) -> Optional[int]:
        """Paper policy: dispatch to the instance with most free memory."""
        alive = [s for s in self.instances.values() if s.alive]
        if not alive:
            return None
        return max(alive, key=lambda s: s.mem_blocks_total -
                   s.mem_blocks_used).inst_id
