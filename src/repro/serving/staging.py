"""Async double-buffered staging of KV pool-row movement.

JAX dispatch is asynchronous: a functional pool update
(``read_pool_rows`` -> ``write_pool_rows`` / ``scatter_pool_rows``)
returns new Array handles immediately while the copies execute behind
the host. Data correctness therefore never depends on WHEN the host
waits — the functional dependencies order every read against every
(donated, in-place) write. What the sync policy does decide is whether
movement traffic hides behind decode compute (paper Fig. 12) or is paid
serially on top of it, and that is exactly what ``AsyncStager`` makes
explicit and measurable:

* ``overlap=False`` — the serial baseline: every staged copy chain is
  ``block_until_ready``-ed at dispatch, the behavior of a synchronous
  DMA engine. Movement time adds to step time.
* ``overlap=True`` — up to ``depth`` copy chains stay in flight
  (double-buffered by default, matching the classic two-slot staging
  buffer); the host blocks only when the ring is full or at an explicit
  ``commit()`` — the table-commit points where a span must be fully
  resident before its tables go live to a consumer that cannot be
  ordered through array dependencies (e.g. handing a pool to another
  process or a benchmark reading raw buffers).

``bench_kv_movement`` A/Bs the two policies (``tps_overlap_on/off``) and
reports the measured break-even next to the paper's modeled
16-tokens/step figure; ``tests/test_zero_copy.py`` asserts the A/B is
token-identical.
"""
from __future__ import annotations

import time
from collections import defaultdict, deque
from typing import Any, Callable, Deque, Dict, Optional, Tuple

import jax

from repro.serving.faults import TransferError, backoff_delay_s


class AsyncStager:
    """Bounded in-flight window over dispatched pool-row copy chains.

    Chains may carry a ``tag`` ("prefetch", "spill", ...): per-tag stall
    counters record how often draining a tagged chain actually had to
    WAIT — the copy was still in flight when the host needed it done.
    ``bench_prefix_cache`` gates prefetch stalls per decode step with
    these.

    Failure handling: draining a chain that raises ``TransferError``
    (e.g. an injected ``FaultPlan`` timeout) is retried up to
    ``max_retries`` times with bounded exponential backoff (counted in
    ``retries`` per tag). On exhaustion — or any non-transient error —
    the failure is counted in ``failures`` per tag, the REMAINING
    in-flight ring is drained to a clean state (secondary errors are
    counted, not raised), and the original error propagates instead of
    being swallowed with a half-populated ring.
    """

    def __init__(self, overlap: bool = True, depth: int = 2, *,
                 max_retries: int = 0, backoff_base_s: float = 0.0,
                 backoff_max_s: float = 0.05):
        self.overlap = overlap
        self.depth = max(1, depth)
        self.max_retries = max(0, max_retries)
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self._inflight: Deque[Tuple[Any, Optional[str]]] = deque()
        self.staged = 0          # copy chains handed to the stager
        self.synced = 0          # explicit block_until_ready calls
        self.sync_wait_s = 0.0   # host time spent blocked on copies
        self.stalls: Dict[str, int] = defaultdict(int)
        self.stall_wait_s: Dict[str, float] = defaultdict(float)
        self.retries: Dict[str, int] = defaultdict(int)
        self.failures: Dict[str, int] = defaultdict(int)
        # Chaos hook: called with the chain's tag before each wait; a
        # True return injects one TransferError (see serving.faults).
        self.fault_hook: Optional[Callable[[Optional[str]], bool]] = None

    def stage(self, arrays: Any, tag: Optional[str] = None) -> None:
        """Register one dispatched copy chain (any pytree of arrays).

        Serial mode blocks immediately; overlap mode admits it into the
        in-flight ring and only drains the OLDEST chain when the ring
        exceeds ``depth`` — the double-buffer rotation.
        """
        self.staged += 1
        if not self.overlap:
            self._block(arrays, tag)
            return
        self._inflight.append((arrays, tag))
        while len(self._inflight) > self.depth:
            self._block(*self._inflight.popleft())

    def commit(self) -> None:
        """Barrier at a table-commit point: drain every in-flight chain."""
        while self._inflight:
            self._block(*self._inflight.popleft())

    def _block(self, arrays: Any, tag: Optional[str] = None) -> None:
        # Retry wrapper around the actual wait. The chain was already
        # popped from the ring by the caller, so a chain that ultimately
        # fails is never left in flight.
        name = tag or "untagged"
        attempt = 0
        while True:
            try:
                self._wait_ready(arrays, tag)
                return
            except TransferError:
                if attempt < self.max_retries:
                    self.retries[name] += 1
                    delay = backoff_delay_s(attempt, self.backoff_base_s,
                                            self.backoff_max_s)
                    if delay > 0:
                        time.sleep(delay)
                    attempt += 1
                    continue
                self.failures[name] += 1
                self._drain_after_failure()
                raise
            except Exception:
                self.failures[name] += 1
                self._drain_after_failure()
                raise

    def _drain_after_failure(self) -> None:
        # Leave the ring EMPTY and consistent after a failed chain:
        # secondary errors while flushing the survivors are counted but
        # not raised (the primary error is the one that propagates).
        pending, self._inflight = list(self._inflight), deque()
        for arrays, tag in pending:
            try:
                self._wait_ready(arrays, tag)
            except Exception:
                self.failures[tag or "untagged"] += 1

    def _wait_ready(self, arrays: Any, tag: Optional[str] = None) -> None:
        if self.fault_hook is not None and self.fault_hook(tag):
            raise TransferError(
                f"injected stager transfer timeout (tag={tag!r})")
        # A staged handle may since have been DONATED into a successor
        # update (the zero-copy chain); its buffer lives on inside the
        # successor, which is itself staged — so deleted handles are
        # simply skipped rather than waited on.
        live = [x for x in jax.tree.leaves(arrays)
                if not (hasattr(x, "is_deleted") and x.is_deleted())]
        stalled = any(not x.is_ready() for x in live
                      if hasattr(x, "is_ready"))
        t0 = time.perf_counter()
        jax.block_until_ready(live)
        waited = time.perf_counter() - t0
        self.sync_wait_s += waited
        self.synced += 1
        if stalled and tag is not None:
            self.stalls[tag] += 1
            self.stall_wait_s[tag] += waited
