"""Host-DRAM KV tier: the memory level below the device block pools.

The paper pools KV across DEVICE memories; this module adds the missing
level of the hierarchy: a bounded store of host-memory block frames that
cold blocks (finished requests' cached prefixes, reclaimed hosted
spans, preempted requests) spill into instead of being dropped, and
from which a prefix-cache hit prefetches them back.

Both directions are ASYNCHRONOUS, mirroring PR 4's movement overlap:

* **Spill (D2H)** — ``put`` takes the device rows (the gather result of
  ``read_pool_rows``; an independent buffer, so the pool block can be
  freed and reused immediately — JAX's functional semantics order the
  gather before any later in-place pool update) and dispatches
  ``copy_to_host_async``. The transfer completes behind decode compute;
  ``drain()`` (called once per cluster step) finalizes whichever
  transfers have landed without blocking.
* **Prefetch (H2D)** — ``get`` returns the host rows; the caller's
  ``write_pool_rows`` dispatch is itself async, so the H2D upload also
  hides behind compute and is only waited on at the admission's
  table-commit point. ``get`` on a spill still in flight must block —
  that is a PREFETCH STALL, counted in ``fetch_stalls`` (the
  ``bench_prefix_cache`` overlap gate divides these by decode steps).

Eviction is LRU with a watermark pair: when occupancy crosses
``high_watermark`` the tier evicts least-recently-used frames down to
``low_watermark``. Pinned keys (an in-flight prefetch chain, an
``evictable_fn`` veto from the prefix cache) are skipped; ``on_evict``
lets the owner drop dependent state — the radix cache deletes the
evicted node's now-unreachable subtree there.
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.faults import (FrameCorruptionError, TransferError,
                                  backoff_delay_s)


@dataclass
class HostTierStats:
    """Counters for tier traffic, stalls, and eviction pressure."""

    spilled_bytes: int = 0       # D2H bytes accepted by put()
    fetched_bytes: int = 0       # H2D bytes handed out by get()
    spills: int = 0
    fetches: int = 0
    fetch_stalls: int = 0        # get() had to block on an in-flight D2H
    stall_wait_s: float = 0.0    # host time spent blocked in stalls
    evictions: int = 0
    rejected: int = 0            # put() refused (tier full of pinned keys)
    fetch_retries: int = 0       # transient fetch errors absorbed by retry
    fetch_failures: int = 0      # fetches that exhausted the retry budget
    corruptions: int = 0         # frames that failed hash verification


class HostKVTier:
    """Bounded LRU store of host-memory KV block frames.

    Keys are content hashes (the radix cache's node hashes) or any
    hashable id; one key maps to ONE block's (k, v) rows of shape
    ``[L, block_size, K, hd]``.

    With ``verify=True`` every frame is checksummed (CRC32 of its raw
    bytes) when the D2H spill finalizes, and every ``get`` re-checks the
    stored bytes against that hash before handing them out — a
    corrupted or swapped frame raises ``FrameCorruptionError`` (and is
    dropped) instead of silently poisoning decode. Transient fetch
    errors (``TransferError``, e.g. an injected chaos fault) are
    retried up to ``max_retries`` times with bounded exponential
    backoff before propagating.
    """

    def __init__(self, capacity_blocks: int, *,
                 high_watermark: float = 0.9, low_watermark: float = 0.7,
                 on_evict: Optional[Callable[[Any], None]] = None,
                 evictable_fn: Optional[Callable[[Any], bool]] = None,
                 verify: bool = False, max_retries: int = 0,
                 backoff_base_s: float = 0.0, backoff_max_s: float = 0.05):
        assert capacity_blocks >= 0
        assert 0.0 < low_watermark <= high_watermark <= 1.0
        self.capacity = capacity_blocks
        self.high = high_watermark
        self.low = low_watermark
        self.on_evict = on_evict
        self.evictable_fn = evictable_fn
        self.verify = verify
        self.max_retries = max(0, max_retries)
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        # key -> (k_np, v_np) finalized frames.
        self._frames: Dict[Any, Tuple[np.ndarray, np.ndarray]] = {}
        # key -> (k_dev, v_dev) with copy_to_host_async dispatched.
        self._pending: Dict[Any, Tuple[Any, Any]] = {}
        self._sums: Dict[Any, int] = {}       # key -> stored-frame CRC32
        self._tick: Dict[Any, int] = {}       # key -> LRU clock value
        self._clock = 0
        self.pinned: set = set()
        self.stats = HostTierStats()
        # Chaos hook: called with the key on each fetch; may return
        # "error" (inject a transient TransferError) or "corrupt"
        # (bit-flip the stored frame). See serving.faults.
        self.fault_hook: Optional[Callable[[Any], Optional[str]]] = None

    # ----------------------------------------------------------------- #
    @property
    def used_blocks(self) -> int:
        """Frames resident or in flight (both count against capacity)."""
        return len(self._frames) + len(self._pending)

    @property
    def free_blocks(self) -> int:
        """Capacity headroom without evicting anything."""
        return max(0, self.capacity - self.used_blocks)

    def __contains__(self, key: Any) -> bool:
        return key in self._frames or key in self._pending

    def pin(self, key: Any) -> None:
        """Exempt ``key`` from LRU eviction until ``unpin``/``drop``.

        The preemptor pins every frame of a paused request's KV chain:
        a paused request must ALWAYS be resumable byte-identically, so
        its frames can never be sacrificed to watermark pressure."""
        self.pinned.add(key)

    def unpin(self, key: Any) -> None:
        """Make ``key`` LRU-evictable again (no-op if not pinned)."""
        self.pinned.discard(key)

    def _touch(self, key: Any) -> None:
        self._clock += 1
        self._tick[key] = self._clock

    # ----------------------------------------------------------------- #
    def put(self, key: Any, k_dev: Any, v_dev: Any) -> bool:
        """Spill one block's device rows to host, asynchronously.

        ``k_dev``/``v_dev``: [L, block_size, K, hd] device arrays that
        do NOT alias the pool (a gather result). Returns False when the
        tier cannot make room (capacity 0 or everything pinned) — the
        caller then simply drops the block, the pre-tier behavior.
        """
        if self.capacity <= 0:
            self.stats.rejected += 1
            return False
        if key in self:
            self._touch(key)
            return True
        if self.used_blocks + 1 > self.capacity and \
                not self._evict_down(self.capacity - 1):
            self.stats.rejected += 1
            return False
        for a in (k_dev, v_dev):
            try:
                a.copy_to_host_async()
            except Exception:
                pass                     # backend without async D2H
        self._pending[key] = (k_dev, v_dev)
        self._touch(key)
        self.stats.spills += 1
        self.stats.spilled_bytes += int(
            k_dev.size * k_dev.dtype.itemsize
            + v_dev.size * v_dev.dtype.itemsize)
        if self.used_blocks > int(self.high * self.capacity):
            self._evict_down(int(self.low * self.capacity))
        return True

    def drain(self, block: bool = False) -> None:
        """Finalize spill transfers that have landed (all of them when
        ``block`` is True). Called once per cluster step so host frames
        materialize behind decode compute, never on its critical path."""
        done: List[Any] = []
        for key, (k, v) in self._pending.items():
            if not block and not (self._is_ready(k) and self._is_ready(v)):
                continue
            self._finalize(key, k, v)
            done.append(key)
        for key in done:
            del self._pending[key]

    def _finalize(self, key: Any, k: Any, v: Any) -> None:
        # The landed host bytes are the frame of record: the content
        # hash every later fetch is verified against is taken HERE.
        frame = (np.asarray(k), np.asarray(v))
        self._frames[key] = frame
        if self.verify:
            self._sums[key] = self._checksum(frame)

    @staticmethod
    def _checksum(frame: Tuple[np.ndarray, np.ndarray]) -> int:
        return zlib.crc32(frame[1].tobytes(),
                          zlib.crc32(frame[0].tobytes()))

    @staticmethod
    def _is_ready(a: Any) -> bool:
        try:
            return bool(a.is_ready())
        except Exception:
            return True

    # ----------------------------------------------------------------- #
    def get(self, key: Any) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Host rows for ``key`` — finalizing (and counting as a stall)
        a spill that is still in flight.

        Raises ``TransferError`` after ``max_retries`` failed fetch
        attempts and ``FrameCorruptionError`` (dropping the frame) when
        verification does not match the stored content hash; returns
        None only for a genuinely absent key (raced eviction)."""
        attempt = 0
        while True:
            try:
                return self._get_once(key)
            except TransferError:
                if attempt >= self.max_retries:
                    self.stats.fetch_failures += 1
                    raise
                self.stats.fetch_retries += 1
                delay = backoff_delay_s(attempt, self.backoff_base_s,
                                        self.backoff_max_s)
                if delay > 0:
                    time.sleep(delay)
                attempt += 1

    def _get_once(self, key: Any) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        if key in self._pending:
            k, v = self._pending.pop(key)
            stalled = not (self._is_ready(k) and self._is_ready(v))
            t0 = time.perf_counter()
            self._finalize(key, k, v)
            if stalled:
                self.stats.fetch_stalls += 1
                self.stats.stall_wait_s += time.perf_counter() - t0
        if key in self._frames and self.fault_hook is not None:
            mode = self.fault_hook(key)
            if mode == "error":
                raise TransferError(f"injected host fetch error "
                                    f"(key={key!r})")
            if mode == "corrupt":
                self._corrupt(key)
        frame = self._frames.get(key)
        if frame is None:
            return None
        if self.verify and key in self._sums and \
                self._checksum(frame) != self._sums[key]:
            self.stats.corruptions += 1
            self.drop(key)
            raise FrameCorruptionError(
                f"host frame {key!r} failed content-hash verification")
        self._touch(key)
        self.stats.fetches += 1
        self.stats.fetched_bytes += int(
            frame[0].nbytes + frame[1].nbytes)
        return frame

    def _corrupt(self, key: Any) -> None:
        # Chaos injection: flip the first byte of the stored K rows —
        # exactly what a wrong/bit-rotted frame looks like to a reader.
        k, v = self._frames[key]
        kb = bytearray(k.tobytes())
        kb[0] ^= 0xFF
        self._frames[key] = (
            np.frombuffer(bytes(kb), dtype=k.dtype).reshape(k.shape), v)

    def drop(self, key: Any) -> None:
        """Forget ``key`` entirely (pending or resident; idempotent)."""
        self._pending.pop(key, None)
        self._frames.pop(key, None)
        self._sums.pop(key, None)
        self._tick.pop(key, None)
        self.pinned.discard(key)

    # ----------------------------------------------------------------- #
    def _evict_down(self, target_blocks: int) -> bool:
        """LRU-evict unpinned frames until occupancy <= target. Returns
        True if the target was reached."""
        order = sorted((k for k in self._tick if k in self),
                       key=lambda k: self._tick[k])
        for key in order:
            if self.used_blocks <= target_blocks:
                break
            if key in self.pinned:
                continue
            if self.evictable_fn is not None and \
                    not self.evictable_fn(key):
                continue
            self.stats.evictions += 1
            if self.on_evict is not None:
                # The owner's hook drops dependent state and is expected
                # to call ``drop(key)`` (the radix cache deletes the
                # node's subtree, which includes this frame).
                self.on_evict(key)
            self.drop(key)               # idempotent if the hook dropped
        return self.used_blocks <= target_blocks


__all__ = ["HostKVTier", "HostTierStats"]
