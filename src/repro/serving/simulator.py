"""Event-driven cluster simulator (perf-model-timed) for Fig. 9/10.

Simulates N serving instances at decode-step granularity with the Eq. 5-7
performance model providing step times for TPU v5e. Three policies:

  "infinite"     — Infinite-LLM: cluster-pooled KV; admission to the
                   instance with most free memory; reactive spill +
                   Algorithm-1 proactive moves; spanning requests pay the
                   coverage-bounded debtor/creditor costs.
  "vllm-multi"   — static instances, no pooling: requests that outgrow
                   the instance are dropped (or never admitted).
  "vllm-single"  — all chips in ONE wide-TP instance: everything fits,
                   but every layer pays the wide-TP all-reduce cost
                   (paper Fig. 1c) and f(beta) saturates per-chip.

Striped spans: every request tracks its creditor placement exactly
(``SimRequest.spans``: inst_id -> hosted tokens). Remote MicroAttention
runs in PARALLEL across a request's creditors, so the debtor's remote
bound is its slowest single-creditor slice — striping over more
creditors shrinks it — while every (request, creditor) span entry pays
per-step query/merge traffic (``InstancePerfModel.t_span_merge``).
``striped=False`` restricts the proactive planner to one creditor per
request (the original single-destination Algorithm 1) for A/B runs.
The symmetric reclaim path evicts hosted spans off a memory-stressed
creditor back to owners or sideways, exactly as the real scheduler does.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.perfmodel import InstancePerfModel


@dataclass
class SimRequest:
    """Analytic-simulator request: lengths + creditor placement only."""

    req_id: int
    arrival: float
    prompt_len: int
    output_len: int
    generated: int = 0
    inst: Optional[int] = None
    # Exact creditor placement: inst_id -> tokens hosted there.
    spans: Dict[int, int] = field(default_factory=dict)
    finish_time: Optional[float] = None
    failed: bool = False

    @property
    def length(self) -> int:
        """Current total tokens (prompt + generated)."""
        return self.prompt_len + self.generated

    @property
    def offloaded(self) -> int:
        """Tokens hosted on creditor instances."""
        return sum(self.spans.values())


@dataclass
class SimInstance:
    """Analytic-simulator instance: perf model + token accounting."""

    inst_id: int
    perf: InstancePerfModel
    kv_capacity_tokens: int
    running: List[SimRequest] = field(default_factory=list)
    hosted_tokens: int = 0
    clock: float = 0.0
    busy_until: float = 0.0
    max_batch: int = 512

    @property
    def local_tokens(self) -> int:
        """Debtor-resident tokens of this instance's running set."""
        return sum(r.length - r.offloaded for r in self.running)

    @property
    def free_tokens(self) -> int:
        """KV capacity left after local + hosted tokens."""
        return self.kv_capacity_tokens - self.local_tokens \
            - self.hosted_tokens

    def step_time(self) -> float:
        """Eq. 5-7 step time of the current batch (all layers)."""
        beta = len(self.running)
        if beta == 0:
            # Hosted-span MicroAttention cost is charged on the debtor
            # side (its coverage-bounded slice time); an instance with
            # no running requests just ticks.
            return 1e-3
        lens = [r.length for r in self.running]
        off = sum(r.offloaded for r in self.running)
        t = self.perf.t_layer(beta, lens)
        per_tok = self.perf.kv_bytes_per_token_layer() / \
            (self.perf.hw.hbm_bw * self.perf.chips)
        off_t = off * per_tok
        # Remote MicroAttention runs in PARALLEL across creditors — the
        # debtor waits only for its slowest single-creditor slice
        # (DistAttention's bandwidth aggregation), still bounded below
        # by local compute (paper Fig. 6a coverage).
        slice_t = max((max(r.spans.values(), default=0)
                       for r in self.running), default=0) * per_tok
        t = max(t - off_t, slice_t)
        t += self.hosted_tokens * per_tok
        # Per-(request, creditor) span entries pay query/merge traffic.
        entries = sum(len(r.spans) for r in self.running)
        t += self.perf.t_span_merge(entries)
        return self.perf.cfg.num_layers * max(t, 1e-9)


class ClusterSimulator:
    """Event-driven analytic cluster sim (paper Figs. 9-10 regimes).

    No tensors: instances advance on ``InstancePerfModel`` step times,
    and the scheduling ``policy`` controls admission/offload — used by
    the e2e-traces benchmark to compare policies at paper scale.
    """

    def __init__(self, cfg: ModelConfig, *, policy: str,
                 n_instances: int, chips_per_instance: int,
                 schedule_every: float = 0.25,
                 avg_new_len: int = 512,
                 striped: bool = True,
                 max_stripes: int = 8):
        self.cfg = cfg
        self.policy = policy
        self.instances: List[SimInstance] = []
        for i in range(n_instances):
            perf = InstancePerfModel(cfg, chips=chips_per_instance)
            cap = perf.kv_tokens_capacity()
            self.instances.append(SimInstance(i, perf, cap))
        self.waiting: List[SimRequest] = []
        self.finished: List[SimRequest] = []
        self.failed: List[SimRequest] = []
        self.schedule_every = schedule_every
        self.clock = 0.0
        self.avg_new_len = avg_new_len
        self.striped = striped
        self.max_stripes = max_stripes if striped else 1
        self._next_sched = schedule_every
        self._requeue: List[SimRequest] = []

    # --------------------------------------------------------------- #
    def _host(self, req: SimRequest, donor: SimInstance, tok: int):
        donor.hosted_tokens += tok
        req.spans[donor.inst_id] = req.spans.get(donor.inst_id, 0) + tok

    def _release_spans(self, req: SimRequest):
        for iid, tok in req.spans.items():
            self.instances[iid].hosted_tokens -= tok
        req.spans = {}

    def _admit(self, req: SimRequest) -> bool:
        insts = sorted(self.instances, key=lambda x: -x.free_tokens)
        for inst in insts:
            if len(inst.running) >= inst.max_batch:
                continue
            if inst.free_tokens >= req.prompt_len:
                inst.running.append(req)
                req.inst = inst.inst_id
                return True
            if self.policy == "infinite":
                # Spill: local tail + remote prefix striped across up to
                # ``max_stripes`` creditors (reserve-then-stream at
                # admission; ONE creditor when striped=False — the
                # single-destination baseline cannot admit a prompt no
                # single creditor can hold).
                need = req.prompt_len - inst.free_tokens
                donors = sorted((d for d in self.instances
                                 if d is not inst and d.free_tokens > 0),
                                key=lambda d: -d.free_tokens)
                donors = donors[:self.max_stripes]
                avail = sum(d.free_tokens for d in donors)
                if avail >= need and inst.free_tokens > 0:
                    req.inst = inst.inst_id
                    inst.running.append(req)
                    for d in donors:
                        take = min(d.free_tokens, need)
                        self._host(req, d, take)
                        need -= take
                        if need <= 0:
                            break
                    return True
        return False

    def _preempt(self, inst: SimInstance, req: SimRequest, t: float):
        """vLLM-style preemption: drop KV, requeue (recompute on resume)."""
        inst.running.remove(req)
        self._release_spans(req)
        req.inst = None
        req.arrival = t                     # back of the queue
        self._requeue.append(req)

    def _spill(self, inst: SimInstance, t: float = 0.0):
        """Reactive: keep the instance under its memory capacity; when the
        cluster pool is exhausted, PREEMPT (never corrupt, never fail)."""
        while inst.free_tokens < 0:
            victim = max(inst.running, key=lambda r: r.length - r.offloaded,
                         default=None)
            if victim is None:
                break
            donors = sorted((d for d in self.instances if d is not inst
                             and d.free_tokens > 256),
                            key=lambda d: -d.free_tokens)
            # The single-destination baseline may only grow the span a
            # victim already has (or open its first); striped mode opens
            # up to max_stripes spans per victim.
            if victim.spans:
                allowed = [d for d in donors
                           if d.inst_id in victim.spans
                           or len(victim.spans) < self.max_stripes]
            else:
                allowed = donors
            chunk = 0
            if allowed:
                chunk = min(-inst.free_tokens + 256,
                            allowed[0].free_tokens,
                            victim.length - victim.offloaded - 256)
            if chunk <= 0:
                self._preempt(inst, victim, t)
                continue
            self._host(victim, allowed[0], chunk)

    def _proactive(self):
        """Algorithm-1 at simulator granularity, striped: the longest
        request of each debtor is placed across creditors, respecting
        the PER-REQUEST ``max_stripes`` span cap — a request may only
        grow spans it already has, or open new ones while it is under
        the cap (so ``striped=False`` is genuinely single-destination
        for each request's lifetime, not per planning round)."""
        debtors = sorted((i for i in self.instances
                          if 0 < len(i.running) <= 8
                          or i.free_tokens < i.kv_capacity_tokens // 10),
                         key=lambda i: len(i.running))
        for d in debtors:
            if not d.running:
                continue
            longest = max(d.running, key=lambda r: r.length - r.offloaded)
            movable = longest.length - longest.offloaded - 256
            if movable < 1024:
                continue
            creditors = sorted(
                (i for i in self.instances if i is not d
                 and i.free_tokens > i.kv_capacity_tokens // 3),
                key=lambda i: -i.free_tokens)
            for c in creditors:
                if movable < 1024:
                    break
                if c.free_tokens < 1024:
                    continue
                if c.inst_id not in longest.spans and \
                        len(longest.spans) >= self.max_stripes:
                    continue
                take = min(movable, c.free_tokens // 2)
                self._host(longest, c, take)
                movable -= take

    def _reclaim(self):
        """Symmetric path: a creditor that became memory-stressed evicts
        hosted spans back to owners or sideways to calm instances."""
        for h in self.instances:
            if h.hosted_tokens <= 0 or \
                    h.free_tokens > h.kv_capacity_tokens // 20:
                continue
            victims = [(r, o) for o in self.instances for r in o.running
                       if r.spans.get(h.inst_id, 0) > 0]
            for req, owner in victims:
                tok = req.spans.get(h.inst_id, 0)
                # Back to the owner when it has real headroom, else
                # sideways to the calmest other instance.
                dst = None
                if owner.free_tokens >= tok + 1024:
                    dst = owner
                else:
                    calm = sorted((i for i in self.instances
                                   if i is not h and i is not owner
                                   and i.free_tokens >= tok + 1024),
                                  key=lambda i: -i.free_tokens)
                    dst = calm[0] if calm else None
                if dst is None:
                    continue
                h.hosted_tokens -= tok
                del req.spans[h.inst_id]
                if dst is not owner:
                    self._host(req, dst, tok)
                if h.free_tokens > h.kv_capacity_tokens // 20:
                    break

    # --------------------------------------------------------------- #
    def run(self, requests: List[SimRequest], *, horizon: float = 600.0
            ) -> Dict[str, float]:
        """Event-driven: every instance advances on its OWN clock (an
        instance hosting heavy MicroAttention slows only itself, as in
        the real asynchronous cluster)."""
        pending = sorted(requests, key=lambda r: r.arrival)
        tokens_done = 0
        heap = [(0.0, i.inst_id) for i in self.instances]
        heapq.heapify(heap)

        while heap and (pending or any(i.running for i in self.instances)):
            t, iid = heapq.heappop(heap)
            if t > horizon:
                break
            self.clock = max(self.clock, t)
            inst = self.instances[iid]

            # Admit arrivals up to this time.
            while pending and pending[0].arrival <= t:
                req = pending[0]
                cap = self.instances[0].kv_capacity_tokens
                if self.policy != "infinite":
                    feasible = req.prompt_len + req.output_len <= cap
                else:
                    # Pooled feasibility: the local tail plus at most
                    # ``max_stripes`` creditor spans. A request no
                    # placement can EVER hold is rejected, not left to
                    # block the queue head forever.
                    pool_span = min(1 + self.max_stripes,
                                    len(self.instances))
                    feasible = req.prompt_len + req.output_len <= \
                        cap * pool_span
                if not feasible:
                    req.failed = True
                    self.failed.append(req)
                    pending.pop(0)
                    continue
                if self._admit(req):
                    pending.pop(0)
                else:
                    break                        # head-of-line wait

            if self.policy == "infinite" and t >= self._next_sched:
                self._reclaim()
                self._proactive()
                self._next_sched = t + self.schedule_every

            if not inst.running:
                # Idle: wake at the next arrival (or a coarse tick if the
                # head of line is blocked on memory elsewhere).
                nxt = (pending[0].arrival if pending else t + 0.05)
                heapq.heappush(heap, (max(nxt, t + 0.05), iid))
                continue

            # One decode step for THIS instance.
            dt = inst.step_time()
            for r in list(inst.running):
                r.generated += 1
                tokens_done += 1
                if r.generated >= r.output_len:
                    r.finish_time = t + dt
                    inst.running.remove(r)
                    self._release_spans(r)
                    self.finished.append(r)
            if self.policy == "infinite":
                self._spill(inst, t)
            if self._requeue:
                pending.extend(self._requeue)
                pending.sort(key=lambda r: r.arrival)
                self._requeue.clear()
            heapq.heappush(heap, (t + dt, iid))

        lat = [r.finish_time - r.arrival for r in self.finished
               if r.finish_time]
        return {
            "throughput_tok_s": tokens_done / max(self.clock, 1e-9),
            "finished": len(self.finished),
            "failed": len(self.failed),
            "p50_latency": float(np.percentile(lat, 50)) if lat else 0.0,
            "p99_latency": float(np.percentile(lat, 99)) if lat else 0.0,
            "clock": self.clock,
        }


def make_policy_cluster(cfg: ModelConfig, policy: str, total_chips: int,
                        chips_per_instance: int, *,
                        striped: bool = True) -> ClusterSimulator:
    """Build the simulator laid out for a named scheduling policy."""
    if policy == "vllm-single":
        return ClusterSimulator(cfg, policy=policy, n_instances=1,
                                chips_per_instance=total_chips,
                                striped=striped)
    n = total_chips // chips_per_instance
    return ClusterSimulator(cfg, policy=policy, n_instances=n,
                            chips_per_instance=chips_per_instance,
                            striped=striped)
