"""One cluster-wide KV pool tensor: the paper's distributed KVCache.

``GlobalKVPool`` folds the per-instance ``pool_k/pool_v`` tensors into
ONE pair of arrays ``k/v: [ranks, L, NB, bs, K, hd]`` whose leading rank
axis is (optionally) sharded over a device mesh (``("data",)`` or
``("data", "model")`` per ``ServeLayout.pool_axes``). Rank ``i``'s slice
``k[i]`` plays exactly the role engine ``i``'s private pool used to play
— same block ids, same tables — but every cross-rank KV access is now a
slice of one tensor:

  * a creditor READ during decode/prefill is a per-shard MicroAttention
    partial under ``shard_map`` (``sharded_step.decode_step_global``) —
    the KV never moves, only the LSE-merge scalars do (paper Eq. 3);
  * a ``StripedMove`` leg, a ``PrefixSink`` streaming write, an
    ``AsyncStager``-staged prefetch — all become slice assignments
    ``k.at[dst_rank, ...].set(...)``, which GSPMD lowers to remote DMA
    between the owning shards when a mesh is attached;
  * allocator state stays HOST metadata: ``ranks[i]`` is the same
    ``RankKVPool`` (block allocator + per-request chains) each engine's
    ``RManager`` would otherwise own privately — engines in global-pool
    mode alias these, so the cluster and the sharded step literally
    share one layout and allocator view.

Zero-copy discipline (PR 4) carries over: every updater donates the
global tensor and callers must continue with the returned handle —
``GlobalKVPool`` threads exactly one live ``self.k``/``self.v``
reference, and ``CommStats.pool_copy_steps`` still gates in-place reuse.

Tail-append convention: same as everywhere else (see the kvpool module
docstring) — block index ``NB`` + ``mode="drop"`` is the universal
"write nothing" sentinel; the rank axis needs no extra masking either,
because an out-of-range shard-local rank index drops the same way.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.serving.kvpool import RankKVPool


# Every updater DONATES the global pool: on donating backends the write
# is an in-place row update of the [R, L, NB, bs, K, hd] tensor. The
# rank indices are STATIC (there are only n_ranks of them, so compiles
# stay bounded). NB on the index mix: in pool.at[rank, :, idx] the int
# rank and the array idx are both ADVANCED indices separated by the
# layer slice, so their broadcast dims land at the FRONT — values are
# [n, L, ...], hence the swapaxes from the [L, n, ...] caller layout.
@functools.partial(jax.jit, static_argnames=("rank",),
                   donate_argnames=("pool",))
def _gp_write_blocks(pool, idx, rows, *, rank):
    val = jnp.swapaxes(rows.astype(pool.dtype), 0, 1)
    return pool.at[rank, :, idx].set(val)


@functools.partial(jax.jit, static_argnames=("rank",),
                   donate_argnames=("pool",))
def _gp_scatter_rows(pool, blk, off, rows, *, rank):
    val = jnp.swapaxes(rows.astype(pool.dtype), 0, 1)
    return pool.at[rank, :, blk, off].set(val)


@functools.partial(jax.jit, static_argnames=("rank",))
def _gp_read_blocks(pool, idx, *, rank):
    return pool[rank][:, idx]


@functools.partial(jax.jit, static_argnames=("src", "dst"),
                   donate_argnames=("pool",))
def _gp_copy_blocks(pool, src_idx, dst_idx, *, src, dst):
    # One StripedMove leg: whole blocks slide from src rank to dst rank
    # inside the tensor. Under a mesh GSPMD lowers this to a remote DMA
    # between the owning shards; no host round-trip, no dense KV array.
    rows = pool[src][:, src_idx]
    return pool.at[dst, :, dst_idx].set(jnp.swapaxes(rows, 0, 1))


class GlobalKVPool:
    """The cluster-wide pool tensor + the per-rank allocator views."""

    def __init__(self, n_ranks: int, num_blocks: int, block_size: int,
                 cfg: ModelConfig, *, mesh=None,
                 pool_axes: Tuple[str, ...] = ("data",)):
        assert cfg.family in ("dense", "moe"), \
            "only attention archs pool KV"
        self.n_ranks = n_ranks
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.mesh = mesh
        self.pool_axes = tuple(pool_axes)
        L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
        dt = jnp.dtype(cfg.dtype)
        self.k = jnp.zeros((n_ranks, L, num_blocks, block_size, K, hd), dt)
        self.v = jnp.zeros((n_ranks, L, num_blocks, block_size, K, hd), dt)
        if mesh is not None:
            n_shards = 1
            for ax in self.pool_axes:
                n_shards *= mesh.shape[ax]
            assert n_ranks % n_shards == 0, \
                f"{n_ranks} ranks not divisible over {n_shards} shards"
            sh = NamedSharding(mesh, P(self.pool_axes))
            self.k = jax.device_put(self.k, sh)
            self.v = jax.device_put(self.v, sh)
        # THE shared allocator view: engine i's RManager aliases
        # ranks[i], so host-side placement metadata is identical whether
        # the step runs in-process or under shard_map.
        self.ranks: List[RankKVPool] = [RankKVPool(num_blocks, block_size)
                                        for _ in range(n_ranks)]

    # --- functional updaters (donated; continue with self.k/self.v) --- #
    def _prep_rows(self, rows, nb: int):
        rows = jnp.asarray(rows)
        pad = nb * self.block_size - rows.shape[1]
        if pad:
            widths = [(0, 0), (0, pad)] + [(0, 0)] * (rows.ndim - 2)
            rows = jnp.pad(rows, widths)
        return rows.reshape((rows.shape[0], nb, self.block_size)
                            + rows.shape[2:])

    def write_blocks(self, rank: int, block_ids: Sequence[int],
                     k_rows, v_rows) -> None:
        """Fill whole blocks of one rank from [L, n, K, hd] token rows
        (n <= len(block_ids) * bs; a partial final block zero-pads)."""
        nb = len(block_ids)
        idx = jnp.asarray(list(block_ids), jnp.int32)
        self.k = _gp_write_blocks(self.k, idx, self._prep_rows(k_rows, nb),
                                  rank=rank)
        self.v = _gp_write_blocks(self.v, idx, self._prep_rows(v_rows, nb),
                                  rank=rank)

    def scatter_rows(self, rank: int, block_ids, offsets, k, v) -> None:
        """Row-addressed scatter into one rank's blocks (may land
        mid-block — the streaming-prefill creditor write)."""
        blk = jnp.asarray(block_ids, jnp.int32)
        off = jnp.asarray(offsets, jnp.int32)
        self.k = _gp_scatter_rows(self.k, blk, off, jnp.asarray(k),
                                  rank=rank)
        self.v = _gp_scatter_rows(self.v, blk, off, jnp.asarray(v),
                                  rank=rank)

    def read_blocks(self, rank: int, block_ids: Sequence[int]):
        """Whole blocks of one rank as ([L, nb*bs, K, hd], same) — a
        gather, safe to hold after the frames are freed."""
        idx = jnp.asarray(list(block_ids), jnp.int32)
        k = _gp_read_blocks(self.k, idx, rank=rank)
        v = _gp_read_blocks(self.v, idx, rank=rank)
        n = len(block_ids) * self.block_size
        return (k.reshape((k.shape[0], n) + k.shape[3:]),
                v.reshape((v.shape[0], n) + v.shape[3:]))

    def copy_blocks(self, src_rank: int, src_blocks: Sequence[int],
                    dst_rank: int, dst_blocks: Sequence[int]) -> None:
        """One StripedMove leg: block i of ``src_blocks`` lands in block
        i of ``dst_blocks`` — a slice assignment inside the tensor
        (remote DMA under GSPMD), never a host materialization."""
        si = jnp.asarray(list(src_blocks), jnp.int32)
        di = jnp.asarray(list(dst_blocks), jnp.int32)
        self.k = _gp_copy_blocks(self.k, si, di, src=src_rank,
                                 dst=dst_rank)
        self.v = _gp_copy_blocks(self.v, si, di, src=src_rank,
                                 dst=dst_rank)


__all__ = ["GlobalKVPool"]
