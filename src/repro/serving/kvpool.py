"""Paged KVCache block pool — allocator, device tables, pool-row writes.

Each serving rank (an ``InstanceEngine`` in the in-process cluster, or an
entry on the ``data``/``model`` mesh axis) owns a fixed pool of
``num_blocks`` blocks of ``block_size`` tokens. Since the pool refactor
this is where ALL serving KV bytes live: each engine holds device tensors
``pool_k/pool_v: [L, num_blocks, block_size, K, hd]``, and the host-side
allocator here hands out the block ids that index them. Per-request
*local tables* (sequence-ordered local block ids, -1 padded, built by
``build_local_tables``) are what the paged MicroAttention step consumes.
Placement across ranks is pure metadata: moving a block = copying pool
rows (``read_pool_rows`` -> ``write_pool_rows``) + editing tables, never
recompilation. Tables are padded to the bucketed widths returned by
``table_bucket`` so the decode step compiles O(#buckets) times, not
O(#sequence-lengths).

Tail-append convention (one scheme, everywhere)
-----------------------------------------------
Every paged step writes the step's new KV rows with a scatter of the
form ``pool.at[wblk, woff].set(..., mode="drop")`` where ``wblk`` is a
block INDEX and the sentinel for "this slot writes nothing" is any
OUT-OF-RANGE index — canonically ``NB`` (one past the last real block).
``mode="drop"`` makes the out-of-bounds write a no-op, so padded batch
slots, ranks that don't own the written row, and suppressed prefill
rows all use the same sentinel and the pool tensor is exactly
``[..., NB, bs, K, hd]`` — no phantom ``NB+1`` dump slot is ever
allocated. (``sharded_step`` historically carried a real extra dump
block; that convention is gone — see its module docstring.)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class BlockAllocator:
    """Fixed-capacity, REFCOUNTED block allocator for one rank's pool.

    Since the cross-request prefix cache, one block frame can be
    referenced by several holders at once — the radix cache (one ref per
    device replica) plus every live request whose chain shares the
    frame. ``alloc`` hands a frame out with refcount 1; ``incref`` adds
    a holder; ``free`` drops one reference per call and only returns the
    frame to the free list when the count reaches zero. Every holder
    therefore keeps its exact single-release discipline (the double-free
    guard still raises on a frame with no live references) while shared
    prefixes never copy.
    """

    def __init__(self, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._owner: Dict[int, int] = {}          # block -> allocating id
        self._ref: Dict[int, int] = {}            # block -> live references
        self.reserved = 0                         # try_move reservations

    @property
    def free_count(self) -> int:
        """Allocatable blocks (free list minus move reservations)."""
        return len(self._free) - self.reserved

    @property
    def used_count(self) -> int:
        """Blocks currently owned by some request/cache."""
        return self.num_blocks - len(self._free)

    def alloc(self, n: int, req_id: int) -> Optional[List[int]]:
        """Allocate ``n`` blocks for ``req_id`` (None if short)."""
        if n > self.free_count:
            return None
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._owner[b] = req_id
            self._ref[b] = 1
        return blocks

    def reserve(self, n: int) -> bool:
        """Reserve capacity ahead of a KV move (try_move_kvcache)."""
        if n > self.free_count:
            return False
        self.reserved += n
        return True

    def commit_reservation(self, n: int, req_id: int) -> List[int]:
        """Turn a prior ``reserve(n)`` into real blocks."""
        assert self.reserved >= n
        self.reserved -= n
        blocks = self.alloc(n, req_id)
        assert blocks is not None
        return blocks

    def cancel_reservation(self, n: int) -> None:
        """Return reserved headroom without allocating."""
        self.reserved = max(0, self.reserved - n)

    def incref(self, blocks: Sequence[int]) -> None:
        """Add one reference per block (prefix-cache sharing)."""
        for b in blocks:
            if b not in self._ref:
                raise KeyError(f"incref of unallocated block {b}")
            self._ref[b] += 1

    def refcount(self, block: int) -> int:
        """Live references on ``block`` (0 if unallocated)."""
        return self._ref.get(block, 0)

    def rebind(self, block: int, new_id: int) -> None:
        """Reassign a block's informational owner id (cache adoption)."""
        if block in self._owner:
            self._owner[block] = new_id

    def free(self, blocks: Sequence[int]) -> None:
        """Drop ONE reference per block; frames return to the free list
        only at refcount zero. Freeing a frame with no live references
        raises (the double-free guard)."""
        for b in blocks:
            refs = self._ref.get(b)
            if refs is None:
                raise KeyError(f"double free of block {b}")
            if refs > 1:
                self._ref[b] = refs - 1
                continue
            del self._ref[b]
            self._owner.pop(b, None)
            self._free.append(b)

    def blocks_of(self, req_id: int) -> List[int]:
        """Blocks whose informational owner is ``req_id``."""
        return [b for b, r in self._owner.items() if r == req_id]


@dataclass
class RequestBlocks:
    """Sequence-ordered block list of one request on one rank."""
    req_id: int
    blocks: List[int] = field(default_factory=list)
    tail_tokens: int = 0       # valid tokens in the LAST block (1..bs)

    def n_tokens(self, block_size: int) -> int:
        """Valid tokens across this request's blocks."""
        if not self.blocks:
            return 0
        return (len(self.blocks) - 1) * block_size + self.tail_tokens


class RankKVPool:
    """One rank's pool: allocator + per-request ordered block lists."""

    def __init__(self, num_blocks: int, block_size: int):
        self.alloc = BlockAllocator(num_blocks, block_size)
        self.block_size = block_size
        self.requests: Dict[int, RequestBlocks] = {}

    # ----------------------------------------------------------------- #
    def append_tokens(self, req_id: int, n: int) -> bool:
        """Extend a request by n tokens, allocating blocks as needed."""
        bs = self.block_size
        rb = self.requests.setdefault(req_id, RequestBlocks(req_id))
        while n > 0:
            if rb.blocks and rb.tail_tokens < bs:
                take = min(n, bs - rb.tail_tokens)
                rb.tail_tokens += take
                n -= take
                continue
            blocks = self.alloc.alloc(1, req_id)
            if blocks is None:
                return False
            rb.blocks.extend(blocks)
            rb.tail_tokens = 0
        return True

    def pop_prefix_blocks(self, req_id: int, n_blocks: int) -> List[int]:
        """Remove the OLDEST n full blocks (for migration to a creditor)."""
        rb = self.requests[req_id]
        n_full = len(rb.blocks) - (1 if rb.tail_tokens < self.block_size
                                   else 0)
        n_blocks = min(n_blocks, max(0, n_full))
        popped, rb.blocks = rb.blocks[:n_blocks], rb.blocks[n_blocks:]
        self.alloc.free(popped)
        if not rb.blocks:
            rb.tail_tokens = 0
        return popped

    def adopt_blocks(self, req_id: int, n_blocks: int,
                     at_front: bool = False) -> Optional[List[int]]:
        """Allocate blocks for KV arriving from another rank (full blocks)."""
        blocks = self.alloc.alloc(n_blocks, req_id)
        if blocks is None:
            return None
        rb = self.requests.setdefault(req_id, RequestBlocks(req_id))
        if at_front:
            rb.blocks = blocks + rb.blocks
            if rb.tail_tokens == 0:
                rb.tail_tokens = self.block_size
        else:
            if rb.blocks and rb.tail_tokens < self.block_size:
                raise ValueError("cannot append full blocks after a "
                                 "partial tail")
            rb.blocks.extend(blocks)
            rb.tail_tokens = self.block_size
        return blocks

    def attach_shared(self, req_id: int, blocks: Sequence[int],
                      tail_tokens: int) -> None:
        """Start a request's chain from already-resident shared blocks
        (prefix-cache hit). Each block gains one reference, so the
        request's normal ``release`` decrefs it without disturbing the
        cache pin or other sharers."""
        rb = self.requests.setdefault(req_id, RequestBlocks(req_id))
        assert not rb.blocks, "attach_shared on a non-empty chain"
        self.alloc.incref(blocks)
        rb.blocks = list(blocks)
        rb.tail_tokens = tail_tokens

    def release(self, req_id: int) -> None:
        """Drop the request's block references (refcounted free)."""
        rb = self.requests.pop(req_id, None)
        if rb and rb.blocks:
            self.alloc.free(rb.blocks)

    def tokens_of(self, req_id: int) -> int:
        """Valid tokens ``req_id`` holds in this pool (0 if none)."""
        rb = self.requests.get(req_id)
        return rb.n_tokens(self.block_size) if rb else 0

    @property
    def memory_utilization(self) -> float:
        """Fraction of pool blocks in use (Algorithm-1 input)."""
        return self.alloc.used_count / self.alloc.num_blocks


TABLE_BUCKET_MIN = 8


def table_bucket(n_blocks: int, lo: int = TABLE_BUCKET_MIN) -> int:
    """Smallest power-of-two table width >= max(n_blocks, lo).

    Bucketing the ``max_blocks`` dimension of the block tables keeps the
    paged decode step's compile count bounded by the number of buckets
    (log2 of the longest context) instead of the number of distinct
    span lengths.
    """
    m = max(int(n_blocks), lo, 1)
    return 1 << (m - 1).bit_length()


# The pool updaters are jitted with the POOL TENSOR DONATED: on backends
# that honor donation the block write is an in-place row update of the
# [L, NB, bs, K, hd] tensor instead of a copy-on-write of the whole pool.
# Callers must treat the passed pool handle as CONSUMED and continue with
# the returned array (stale-handle discipline; see engine.InstanceEngine,
# which threads one live pool reference functionally).
@functools.partial(jax.jit, donate_argnames=("pool",))
def _write_pool_rows_jit(pool, idx, rows):
    return pool.at[:, idx].set(rows.astype(pool.dtype))


@functools.partial(jax.jit, donate_argnames=("pool",))
def _scatter_pool_rows_jit(pool, blk, off, rows):
    return pool.at[:, blk, off].set(rows.astype(pool.dtype))


def write_pool_rows(pool: jax.Array, block_ids: Sequence[int],
                    rows: jax.Array, block_size: int) -> jax.Array:
    """Write token rows into pool blocks (functional update, pool donated).

    pool: [L, NB, bs, K, hd] — CONSUMED: the caller must drop its handle
    and use the returned array; rows: [L, n, K, hd] with
    n <= len(block_ids) * block_size, filling ``block_ids`` in sequence
    order from offset 0 (a partial final block is zero-padded; readers
    mask it via the table's tail length).
    """
    L, n = rows.shape[:2]
    nb = len(block_ids)
    pad = nb * block_size - n
    if pad:
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (rows.ndim - 2)
        rows = jnp.pad(rows, widths)
    rows = rows.reshape((L, nb, block_size) + rows.shape[2:])
    idx = jnp.asarray(list(block_ids), jnp.int32)
    return _write_pool_rows_jit(pool, idx, rows)


def read_pool_rows(pool: jax.Array, block_ids: Sequence[int],
                   block_size: int) -> jax.Array:
    """Gather full blocks out of a pool: [L, len(block_ids)*bs, K, hd]."""
    idx = jnp.asarray(list(block_ids), jnp.int32)
    rows = pool[:, idx]                       # [L, nb, bs, K, hd]
    L = rows.shape[0]
    return rows.reshape((L, len(block_ids) * block_size) + rows.shape[3:])


def rows_for_token_range(blocks: Sequence[int], block_size: int,
                         t0: int, t1: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-token (block id, in-block offset) for rank-local tokens [t0, t1).

    ``blocks`` is a request's sequence-ordered block list on ONE rank;
    token ``t`` of that rank-local span lives at
    ``(blocks[t // bs], t % bs)``. This is the addressing the streaming
    prefill chunk writer uses to scatter KV rows into pre-reserved
    blocks without ever materializing a dense cache.
    """
    pos = np.arange(t0, t1)
    blk = np.asarray(blocks, np.int32)[pos // block_size]
    off = (pos % block_size).astype(np.int32)
    return blk, off


def scatter_pool_rows(pool: jax.Array, block_ids, offsets,
                      rows: jax.Array) -> jax.Array:
    """Row-addressed scatter into a pool (functional update, pool donated).

    pool: [L, NB, bs, K, hd] — CONSUMED, continue with the returned
    array; rows: [L, n, K, hd] written at ``(block_ids[i], offsets[i])``
    per row — unlike ``write_pool_rows`` this can land mid-block, which
    is what per-chunk streaming writes into already-committed creditor
    blocks need.
    """
    blk = jnp.asarray(block_ids, jnp.int32)
    off = jnp.asarray(offsets, jnp.int32)
    return _scatter_pool_rows_jit(pool, blk, off, rows)


def prefix_tables(pools: Sequence[RankKVPool], req_id: int,
                  covered: Sequence[int], max_blocks: int
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Tables/tails addressing only the first ``covered[p]`` tokens of one
    request on each rank — the streaming-prefill view of a request whose
    blocks are all reserved up front but only partially written.

    Returns (tables [n_ranks, 1, max_blocks] int32 -1-padded,
             tail_len [n_ranks, 1] int32); a rank with zero coverage gets
    an empty table (its MicroAttention partial is the monoid identity).
    """
    P = len(pools)
    tables = -np.ones((P, 1, max_blocks), np.int32)
    tails = np.zeros((P, 1), np.int32)
    for p, pool in enumerate(pools):
        bs = pool.block_size
        c = int(covered[p])
        rb = pool.requests.get(req_id)
        if not rb or c <= 0:
            tails[p, 0] = bs
            continue
        nb = -(-c // bs)
        tables[p, 0, :nb] = rb.blocks[:nb]
        tails[p, 0] = c - (nb - 1) * bs
    return tables, tails


def build_local_tables(pools: Sequence[RankKVPool], req_ids: Sequence[int],
                       max_blocks: int) -> Tuple[np.ndarray, np.ndarray]:
    """Device inputs for the paged kernel across ranks.

    Returns (tables [n_ranks, R, max_blocks] int32 -1-padded,
             tail_len [n_ranks, R] int32).
    """
    n_ranks, R = len(pools), len(req_ids)
    tables = -np.ones((n_ranks, R, max_blocks), np.int32)
    tails = np.full((n_ranks, R), 0, np.int32)
    for p, pool in enumerate(pools):
        for r, rid in enumerate(req_ids):
            rb = pool.requests.get(rid)
            if not rb or not rb.blocks:
                tails[p, r] = pool.block_size
                continue
            n = min(len(rb.blocks), max_blocks)
            tables[p, r, :n] = rb.blocks[:n]
            tails[p, r] = (rb.tail_tokens if n == len(rb.blocks)
                           else pool.block_size)
    return tables, tails
