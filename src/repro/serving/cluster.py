"""Cluster runtime: N instances + gManager, KV movement, fault tolerance.

In-process realization of the paper's Fig. 3/8 system: every instance is
an ``InstanceEngine`` with an ``RManager``; a ``GManager`` ingests
heartbeats, plans Algorithm-1 moves, and the runtime executes them with
the try_move reservation protocol. All serving KV lives in the engines'
device-resident block pools, so every movement here is pool row copies
plus table edits. Two movement protocols exist:

  * **reserve-then-stream** (admission): a prompt whose prefix
    overflows the owner's local quota gets its creditor blocks
    committed BEFORE any prefill compute (``PrefixSink``; may stripe
    the prefix across several creditors when no single one can hold
    it). The owner's chunked paged prefill then streams each chunk's
    creditor-bound KV rows into those blocks as they are computed — no
    dense prefix array is ever materialized.
  * **read-copy-free** (decode-time moves, reactive or Algorithm-1):
    read the oldest blocks out of the debtor's pool, write them into
    blocks reserved in the creditor's pool, free the debtor's blocks.
    Algorithm-1 plans are STRIPED: one ``MoveKVCache`` may carry legs
    for several creditors (or, for reclaim plans, evict a hosted span
    back to its owner / sideways); every leg is reserved before any
    byte moves and one refusal rolls the whole plan back.

Both protocols DISPATCH their pool-row copies through the cluster's
``AsyncStager`` (``async_movement=True``): up to two copy chains stay
in flight behind decode compute, and the host blocks only at
table-commit points (``PrefixSink.flush`` at end of admission) or when
the double buffer overflows — ``async_movement=False`` is the serial
baseline that ``bench_kv_movement`` A/Bs against (tps_overlap_on/off).
Reclaim plans additionally pass the scheduler's Eq. 5-7 gain-vs-cost
check before they are emitted at all (cost-aware undo of a stripe).

Requests whose KV spans instances decode via the owner's multi-rank
``decode_step_paged`` merge (the creditor pools are read directly,
block-table addressed); only query/merge-size traffic is charged per
(request, creditor) span.

Fault tolerance (``serving.faults`` is the chaos side): an instance
that misses ``FaultPolicy.heartbeat_timeout_steps`` consecutive
heartbeats (or the wall-clock timeout) is marked DEAD and quarantined —
no new creditor legs, its view leaves Algorithm-1 planning, its
allocator is drained wholesale (in global-pool mode the dead rank is a
quarantined slice of the one tensor). Every request that lost KV on the
dead rank — owned locally OR creditor-hosted — is recovered by TOKEN
REPLAY: its emitted tokens are known, so the lost KV is exactly
recomputable by re-prefilling ``prompt + output[:-1]`` through the
normal paged admission path (no resampling; the greedy continuation is
byte-identical to an unfailed run). Transfer failures retry with
bounded backoff; a move stripe whose leg fails mid-execution rolls back
exactly and re-plans against surviving creditors.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.config import ServingConfig
from repro.serving.engine import InstanceEngine
from repro.serving.faults import FaultInjector, FaultPlan, FaultStats
from repro.serving.gmanager import GManager
from repro.serving.hosttier import HostKVTier
from repro.serving.kvpool import rows_for_token_range
from repro.serving.perfmodel import InstancePerfModel
from repro.serving.prefixcache import RadixPrefixCache
from repro.serving.protocol import MoveKVCache, MoveLeg, MoveResult
from repro.serving.request import Request, RequestState
from repro.serving.staging import AsyncStager


def reserve_all_or_nothing(req_id: int, legs) -> bool:
    """FCFS-reserve every (rmanager, n_blocks) leg of a striped plan.

    Paper Fig. 8 step 4 generalized to multi-destination plans: either
    EVERY destination accepts its reservation or every reservation made
    so far is cancelled — allocator state is restored exactly and the
    caller sees a clean REJECTED. ``legs``: [(rmanager, n_blocks)].
    """
    reserved = []
    for rm, n in legs:
        if not rm.try_move_kvcache(req_id, n):
            for rm2, m in reserved:
                rm2.cancel_move_in(m)
            return False
        reserved.append((rm, n))
    return True


class PrefixSink:
    """Reserve-then-stream placement of a prompt prefix on creditors.

    Built before any prefill FLOPs are spent: every creditor block the
    [0, n_tokens) prefix needs is already reserved (try_move, FCFS) and
    committed, so admission can only fail while it is still free to
    fail. The owner's chunk loop then calls ``write`` once per chunk to
    scatter the creditor-bound KV rows into those blocks.
    """

    def __init__(self, cluster: "Cluster", req_id: int,
                 spans: List[Tuple[int, int, List[int]]]):
        self._cluster = cluster
        self._req_id = req_id
        self._spans = spans          # [(inst, start_token, block_ids)]
        self._bs = cluster.block_size

    @property
    def spans(self) -> List[Tuple[int, int, List[int]]]:
        """Committed ``(inst, start_token, block_ids)`` spans, in
        global token order — the creditor part of the request's chain."""
        return [(d, st, list(b)) for d, st, b in self._spans]

    @property
    def rank_ids(self) -> List[int]:
        """Creditor instance ids, deduplicated, in prefix order."""
        out: List[int] = []
        for d, _, _ in self._spans:
            if d not in out:
                out.append(d)
        return out

    def coverage(self, upto: int) -> Dict[int, int]:
        """Tokens of the written prefix [0, upto) held per creditor."""
        cov = {d: 0 for d in self.rank_ids}
        for d, start, blocks in self._spans:
            cov[d] += min(max(upto - start, 0), len(blocks) * self._bs)
        return cov

    def row_targets(self, t0: int, t1: int):
        """Per-token (rank, block, offset) of global tokens [t0, t1)
        in the committed creditor spans — the global-pool prefill step
        writes creditor rows itself with these (one deferred scatter
        replaces the ``write``/host_kv_rows round trip)."""
        n = t1 - t0
        ranks = np.zeros(n, np.int32)
        blks = np.zeros(n, np.int32)
        offs = np.zeros(n, np.int32)
        for d, start, blocks in self._spans:
            lo = max(t0, start)
            hi = min(t1, start + len(blocks) * self._bs)
            if lo >= hi:
                continue
            b, o = rows_for_token_range(blocks, self._bs,
                                        lo - start, hi - start)
            ranks[lo - t0:hi - t0] = d
            blks[lo - t0:hi - t0] = b
            offs[lo - t0:hi - t0] = o
        return ranks, blks, offs

    def write(self, t0: int, k, v) -> None:
        """Scatter global prefix rows [t0, t0 + n) into creditor pools.

        k/v: [L, n, K, hd] — one prefill chunk's creditor-bound rows.
        The scatters are DISPATCHED here and staged on the cluster's
        ``AsyncStager``; they complete behind the next chunk's compute
        (or the cluster's decode) and are only drained at ``flush()``,
        the admission's table-commit point.
        """
        n = k.shape[1]
        for d, start, blocks in self._spans:
            lo = max(t0, start)
            hi = min(t0 + n, start + len(blocks) * self._bs)
            if lo >= hi:
                continue
            blk, off = rows_for_token_range(blocks, self._bs,
                                            lo - start, hi - start)
            eng = self._cluster.engines[d]
            eng.host_kv_rows(
                self._req_id, blk, off,
                k[:, lo - t0:hi - t0], v[:, lo - t0:hi - t0])
            self._cluster.stager.stage((eng.pool_k, eng.pool_v))

    def flush(self) -> None:
        """Drain every staged creditor write (end-of-admission commit)."""
        self._cluster.stager.commit()

    def abort(self) -> None:
        """Cancellation rollback: drain any staged (possibly in-flight)
        row writes, then release every committed creditor span — the
        same all-or-nothing metadata rollback a refused stripe takes.
        The written rows become garbage in freed blocks; allocator
        state is restored exactly."""
        self._cluster.stager.commit()
        for d in self.rank_ids:
            self._cluster.engines[d].drop_hosted(self._req_id)


class Cluster:
    """N ``InstanceEngine``s + one ``GManager`` driven in lock-step.

    Owns the shared ``AsyncStager`` (all KV movement), the optional
    ``GlobalKVPool``/host tier/prefix cache, and — when
    ``config.overload.enabled`` — the ``Preemptor``. ``step()`` is the
    cluster heartbeat: resume paused requests, step every live engine,
    run the Algorithm-1 plan round, execute moves, drain releases.
    """

    def __init__(self, params, cfg: ModelConfig,
                 config: Optional[ServingConfig] = None, *,
                 perf: Optional[InstancePerfModel] = None,
                 mesh=None, layout=None):
        config = config if config is not None else ServingConfig()
        self.cfg = cfg
        self.config = config
        self.block_size = config.block_size
        self.move_chunk = config.move_chunk_tokens
        self.schedule_every = config.schedule_every
        # All stripe/offload/reclaim row copies and streaming-prefill
        # creditor writes go through one double-buffered stager:
        # async_movement=True overlaps them with decode compute,
        # False is the serial baseline (bench_kv_movement A/Bs the two).
        fpol = config.faults
        self.stager = AsyncStager(overlap=config.async_movement,
                                  max_retries=fpol.max_transfer_retries,
                                  backoff_base_s=fpol.retry_backoff_base_s,
                                  backoff_max_s=fpol.retry_backoff_max_s)
        # Global-pool mode: ONE [n_instances, L, NB, bs, K, hd] tensor
        # holds every instance's KV (optionally sharded over ``mesh``
        # per ``layout.pool_axes``); every engine aliases its rank's
        # slice + allocator, moves become intra-tensor slice copies and
        # decode/prefill run decode_step_global / prefill_chunk_global.
        self.mesh = mesh
        self.gpool = None
        if config.global_pool and cfg.family in ("dense", "moe"):
            from repro.serving.globalpool import GlobalKVPool
            pool_axes = (tuple(layout.pool_axes) if layout is not None
                         else ("data",))
            if mesh is not None:
                import jax
                from jax.sharding import NamedSharding
                from jax.sharding import PartitionSpec as P
                # Params (and step scalars) replicate over the mesh so
                # GSPMD only ever shards the pool's rank axis.
                params = jax.device_put(params,
                                        NamedSharding(mesh, P()))
            self.gpool = GlobalKVPool(config.n_instances,
                                      config.pool_blocks,
                                      config.block_size, cfg, mesh=mesh,
                                      pool_axes=pool_axes)
        self.engines: Dict[int, InstanceEngine] = {
            i: InstanceEngine(params, cfg, max_batch=config.max_batch,
                              max_local_len=config.max_local_len,
                              pool_blocks=config.pool_blocks,
                              block_size=config.block_size, inst_id=i,
                              prefill_chunk=config.prefill_chunk,
                              gpool=self.gpool)
            for i in range(config.n_instances)
        }
        for eng in self.engines.values():
            eng.prefix_sink = self._make_prefix_sink(eng.inst_id)
            eng.peers = self.engines      # shared: add_instance updates all
        # Host-DRAM tier + cross-request prefix cache (both opt-in).
        self.host_tier: Optional[HostKVTier] = None
        self.prefix_cache: Optional[RadixPrefixCache] = None
        if config.host_tier_blocks > 0:
            self.host_tier = HostKVTier(
                config.host_tier_blocks,
                high_watermark=config.host_high_watermark,
                low_watermark=config.host_low_watermark,
                verify=fpol.verify_host_frames,
                max_retries=fpol.max_transfer_retries,
                backoff_base_s=fpol.retry_backoff_base_s,
                backoff_max_s=fpol.retry_backoff_max_s)
        if config.prefix_cache:
            self.prefix_cache = RadixPrefixCache(self,
                                                 host_tier=self.host_tier)
            for eng in self.engines.values():
                self._wire_cache(eng)
        perf = perf if perf is not None else InstancePerfModel(cfg)
        self.gmanager = GManager(perf, config.block_size,
                                 heartbeat_timeout=config.heartbeat_timeout,
                                 beta_thres=config.beta_threshold,
                                 mem_util_thres=config.mem_util_thres,
                                 avg_new_req_len=config.avg_new_req_len,
                                 max_stripes=config.max_stripes,
                                 reclaim_horizon_s=config.reclaim_horizon_s,
                                 arrival_alpha=config.overload.arrival_alpha,
                                 heartbeat_timeout_steps=(
                                     fpol.heartbeat_timeout_steps))
        # Overload survival (opt-in): pause/host-spill preemption with
        # its own pinned host tier, driven by the serving frontend.
        self.preemptor = None
        if config.overload.enabled:
            from repro.serving.preempt import Preemptor
            self.preemptor = Preemptor(self, config.overload)
        self.requests: Dict[int, Request] = {}
        self._step_count = 0
        self._dead: set = set()
        self.fault_stats = FaultStats()
        self.faults: Optional[FaultInjector] = None
        self._need_full_hb: set = set(self.engines)
        # Req ids whose creditor-hosted spans still need releasing; fed
        # by the engines' finished-event drains so each finished request
        # is released exactly once (never a rescan of all history).
        self._pending_release: set = set()

    # ----------------------------------------------------------------- #
    def submit(self, req: Request, now: Optional[float] = None) -> None:
        """Register ``req`` and enqueue it on the instance Algorithm 1
        picks (least-loaded engine before any heartbeat exists)."""
        if req.req_id not in self.requests and req.arrival_time == 0.0:
            req.arrival_time = time.monotonic() if now is None else now
        self.requests[req.req_id] = req
        inst = self.gmanager.pick_instance_for_new_request()
        if inst is None or inst in self._dead:
            # Bootstrap: no heartbeats yet -> least-loaded engine.
            live = [e for i, e in self.engines.items()
                    if i not in self._dead]
            inst = min(live, key=lambda e: e.batch_size).inst_id
        self.engines[inst].submit(req)

    def submit_to(self, req: Request, inst_id: int,
                  now: Optional[float] = None) -> None:
        """Targeted ``submit``: enqueue on a SPECIFIC live instance —
        the preemption path pairs a paused victim's freed slot with the
        urgent request it was freed for, bypassing the most-free-memory
        placement query."""
        if req.req_id not in self.requests and req.arrival_time == 0.0:
            req.arrival_time = time.monotonic() if now is None else now
        self.requests[req.req_id] = req
        assert inst_id in self.engines and inst_id not in self._dead
        self.engines[inst_id].submit(req)

    def cancel(self, req_id: int) -> bool:
        """Cancel a request anywhere in its lifecycle.

        Propagates through every layer: the owning engine's slot (or
        waiting queue) is released, an in-flight streaming prefill is
        flagged and aborts at its next chunk boundary (rolling back its
        ``PrefixSink`` creditor reservations), every creditor-hosted
        span is dropped exactly once, and any planned-but-unexecuted
        ``MoveKVCache`` for the request resolves ``MoveResult.GONE``
        (``_execute_move`` checks ``req.done`` before reserving, so a
        racing plan can never leave orphan reservations). Returns True
        if the request was live when cancelled.
        """
        req = self.requests.get(req_id)
        if req is None or req.done:
            return False
        req.cancelled = True
        # A PAUSED request lives in no engine — its device state was
        # already released at pause; retire it from the preempt tier.
        if self.preemptor is not None and \
                self.preemptor.cancel_paused(req_id):
            return True
        for i, eng in self.engines.items():
            if i in self._dead:
                continue
            if eng.cancel(req):
                break
        # Mid-streaming-prefill: the engine's chunk loop owns the
        # rollback; hosted spans are released when its finished event
        # drains. For every other state the request is terminal now —
        # release creditor-hosted spans immediately so allocator state
        # is clean the moment cancel() returns.
        if req.done:
            for eng in self.engines.values():
                if eng.rmanager.is_hosting(req_id):
                    eng.drop_hosted(req_id)
        return True

    def _wire_cache(self, eng: InstanceEngine) -> None:
        """Install the prefix cache's hooks on one engine: the engine's
        admission walks/inserts it, and the rManager treats unpinned
        replicas as reclaimable capacity (evicting on demand)."""
        cache = self.prefix_cache
        eng.prefix_cache = cache
        inst = eng.inst_id
        eng.rmanager.evict_hook = \
            lambda n, _i=inst: cache.evict_device(_i, n)
        eng.rmanager.cache_blocks_fn = \
            lambda _i=inst: cache.evictable(_i)

    # --- movement ------------------------------------------------------ #
    def _make_prefix_sink(self, src_id: int):
        """Reserve-then-stream prefix sink for streaming paged prefill.

        ``sink(req, n_tokens, start=0)`` commits whole blocks covering
        the block-aligned GLOBAL token range [start, start + n_tokens)
        across one or more creditors (striping when no single creditor
        can hold it; ``start`` > 0 when a cached prefix already covers
        the head of the prompt) and returns the ``PrefixSink`` the
        owner's chunk loop writes through — or None when the cluster is
        out of pooled memory, with every partial reservation rolled
        back and zero compute spent. Creditors count their unpinned
        prefix-cache replicas as capacity (try_move evicts on demand).

        ``prefer`` (``[(inst_id, n_blocks)]``, chain order) asks the
        sink to reproduce a specific span layout before falling back to
        the generic creditor picker — preemption resume passes the
        paused chain's layout so the restored request keeps its exact
        LSE-merge partition. Entries naming dead instances or the owner
        itself are skipped (their blocks fall through to the generic
        picker), so ``prefer`` is best-effort and never blocks a
        resume that generic placement could satisfy."""
        def sink(req: Request, n_tokens: int, start: int = 0,
                 prefer: Optional[List[Tuple[int, int]]] = None,
                 ) -> Optional[PrefixSink]:
            bs = self.block_size
            spans: List[Tuple[int, int, List[int]]] = []

            def rollback():
                for d, _, _ in spans:
                    self.engines[d].drop_hosted(req.req_id)

            def take(dst: int, nb: int, off: int) -> int:
                """Reserve up to ``nb`` blocks on ``dst``; 0 on refusal."""
                eng = self.engines[dst]
                nb = min(nb, eng.rmanager.effective_free)
                if nb <= 0 or not eng.rmanager.try_move_kvcache(
                        req.req_id, nb):
                    return 0
                blocks = eng.rmanager.commit_move_in(req.req_id, nb,
                                                     at_front=False)
                spans.append((dst, start + off, blocks))
                return nb

            off = 0
            for dst, nb in (prefer or []):
                if off >= n_tokens:
                    break
                if dst == src_id or dst in self._dead \
                        or dst not in self.engines:
                    continue
                nb = min(nb, (n_tokens - off) // bs)
                off += take(dst, nb, off) * bs
            while off < n_tokens:
                dst = self._pick_creditor(exclude=src_id)
                if dst is None:
                    rollback()
                    return None
                nb = take(dst, (n_tokens - off) // bs, off)
                if nb <= 0:
                    rollback()
                    return None
                off += nb * bs
            return PrefixSink(self, req.req_id, spans)
        return sink

    def _execute_move(self, mv: MoveKVCache) -> MoveResult:
        """Execute one striped plan: the oldest blocks of a request's
        span on ``src_inst`` stream onto one or more destinations.

        All-or-nothing: EVERY leg is reserved on its destination first
        (try_move_kvcache, FCFS); if any leg is refused all reservations
        are cancelled and nothing moved. Only then does each leg copy
        pool rows + edit tables — no dense KV arrays are ever
        materialized outside the pools. Handles both offload plans
        (src = owner, keep the live tail local) and reclaim plans
        (src = a stressed creditor; a leg whose destination is the
        OWNER re-adopts blocks at the FRONT of its local span)."""
        if mv.src_inst in self._dead or \
                any(leg.dst_inst in self._dead for leg in mv.legs):
            return MoveResult.REJECTED
        src = self.engines[mv.src_inst]
        req = self.requests.get(mv.req_id)
        if req is None or req.done or req.slot is None:
            return MoveResult.GONE
        owner = next((e for e in self.engines.values()
                      if e.inst_id not in self._dead and req in e.running),
                     None)
        if owner is None:
            return MoveResult.GONE
        bs = self.block_size
        if mv.src_inst == owner.inst_id:
            # Offload: only full blocks, keep the live tail local.
            budget = max(0, src.local_tokens(req) - bs) // bs
        else:
            # Reclaim: src hosts a whole-block span (or the plan is
            # stale and the span is gone).
            rb = src.rmanager.pool.requests.get(mv.req_id)
            budget = len(rb.blocks) if rb is not None else 0
        # Clamp legs in order against what src can actually give up.
        legs = []
        for leg in mv.legs:
            n = min(leg.num_blocks, budget)
            if n <= 0:
                continue
            if leg.dst_inst == owner.inst_id and mv.src_inst != \
                    owner.inst_id:
                # Re-adopting at the owner must respect its local quota
                # (headroom for the next decode append included).
                room = (owner.max_local_len - owner.local_tokens(req)
                        - bs) // bs
                n = min(n, max(0, room))
                if n <= 0:
                    continue
            legs.append((leg.dst_inst, n))
            budget -= n
        if not legs:
            return MoveResult.GONE
        # Paper Fig. 8 step 4, striped: FCFS reservation on EVERY
        # destination before any KV byte moves; one refusal rolls every
        # reservation back.
        if not reserve_all_or_nothing(
                mv.req_id,
                [(self.engines[d].rmanager, n) for d, n in legs]):
            return MoveResult.REJECTED
        # Commit: each leg is pool-row copies + table edits, oldest
        # blocks first so the source span drains front-to-back. The
        # copies are DISPATCHED and staged, not waited for — the table
        # edits are host metadata and the functional array dependencies
        # order any later read of the destination rows after the write;
        # the stager only bounds how many chains stay in flight
        # (serial mode blocks each one: the A/B baseline).
        # The owner's sequence-ordered global chain (req_chain) feeds
        # satellite prefix-cache insertion for spanning requests; a
        # fully-local request gets one lazily on its first move so the
        # rewrite below can track every relocated block.
        if owner.req_chain.get(mv.req_id) is None:
            rb0 = owner.rmanager.pool.requests.get(mv.req_id)
            if rb0 is not None:
                owner.req_chain[mv.req_id] = [(owner.inst_id, b)
                                              for b in rb0.blocks]
        failed_tail: List[Tuple[int, int]] = []
        executed = 0
        for li, (dst_id, n) in enumerate(legs):
            if self.faults is not None and \
                    self.faults.take_move_leg_fault():
                # Injected mid-stripe leg failure: this leg and every
                # later one are still only RESERVATIONS (their
                # commit_move_in has not run) — cancel them exactly.
                # Already-executed legs keep their consistent placement;
                # the un-moved tail re-plans below against a surviving
                # creditor outside the failed stripe.
                self.fault_stats.move_leg_failures += 1
                for dj, nj in legs[li:]:
                    self.engines[dj].rmanager.cancel_move_in(nj)
                failed_tail = legs[li:]
                break
            dst = self.engines[dst_id]
            src_blocks = list(
                src.rmanager.pool.requests[mv.req_id].blocks[:n])
            if self.gpool is not None:
                # Global-pool mode: the leg is ONE intra-tensor slice
                # copy between rank slices (remote DMA under GSPMD when
                # the pool is mesh-sharded) + allocator/table edits.
                blocks = dst.rmanager.commit_move_in(
                    mv.req_id, n, at_front=(dst_id == owner.inst_id))
                self.gpool.copy_blocks(src.inst_id, src_blocks,
                                       dst.inst_id, blocks)
                self.stager.stage((self.gpool.k, self.gpool.v))
                src.rmanager.move_out_prefix(mv.req_id, n)
                c = self.cfg
                nbytes = (2 * c.num_layers * n * bs * c.num_kv_heads *
                          c.head_dim) * self.gpool.k.dtype.itemsize
            else:
                k, v = src.extract_prefix_kv(req, n)
                blocks = dst.rmanager.commit_move_in(
                    mv.req_id, n, at_front=(dst_id == owner.inst_id))
                dst.host_kv(mv.req_id, blocks, k, v)
                self.stager.stage((dst.pool_k, dst.pool_v))
                src.rmanager.move_out_prefix(mv.req_id, n)
                nbytes = int(k.size + v.size) * k.dtype.itemsize
            if dst_id != owner.inst_id:
                insts = owner.remote_insts.setdefault(mv.req_id, [])
                if dst_id not in insts:
                    insts.append(dst_id)
            src.stats.kv_moved += nbytes
            src.stats.tokens_moved_steps.append(n * bs)
            # Rewrite the chain entries in place (ID-based: the moved
            # blocks keep their position in the global token order).
            chain = owner.req_chain.get(mv.req_id)
            if chain is not None and blocks is not None:
                remap = {(mv.src_inst, sb): (dst_id, nb)
                         for sb, nb in zip(src_blocks, blocks)}
                for ci, e in enumerate(chain):
                    if e in remap:
                        chain[ci] = remap.pop(e)
            executed += 1
        if failed_tail:
            # Re-plan the un-moved tail onto a surviving creditor
            # OUTSIDE the failed stripe (source and every failed
            # destination excluded). One recursive attempt — a still-
            # armed fault bounds itself by being consumed above — and
            # no alternative simply leaves the tail where it was for
            # the next reactive/planning round.
            n_rest = sum(n for _, n in failed_tail)
            alt = self._pick_creditor(
                exclude={mv.src_inst} | {d for d, _ in failed_tail})
            if alt is not None:
                res = self._execute_move(MoveKVCache(
                    mv.req_id, mv.src_inst, [MoveLeg(alt, n_rest)]))
                if res == MoveResult.OK:
                    self.fault_stats.move_leg_replans += 1
                    return MoveResult.OK
            return MoveResult.OK if executed else MoveResult.REJECTED
        # A reclaim that drained the source span drops it from the
        # owner's span map (and frees the host's metadata).
        if mv.src_inst != owner.inst_id and \
                not src.rmanager.pool.tokens_of(mv.req_id):
            src.drop_hosted(mv.req_id)
            insts = owner.remote_insts.get(mv.req_id)
            if insts and mv.src_inst in insts:
                insts.remove(mv.src_inst)
                if not insts:
                    owner.remote_insts.pop(mv.req_id, None)
        return MoveResult.OK

    def _reactive_moves(self) -> None:
        """Ship prefix blocks before a request breaches its local quota."""
        for eng in self.engines.values():
            if eng.inst_id in self._dead or not eng._can_pool:
                continue
            for req in eng.running:
                if eng.local_free_tokens(req) <= 1:
                    dst = self._pick_creditor(exclude=eng.inst_id)
                    n_blocks = max(1, self.move_chunk // self.block_size)
                    ok = (dst is not None and
                          self._execute_move(MoveKVCache(
                              req.req_id, eng.inst_id,
                              [MoveLeg(dst, n_blocks)]))
                          == MoveResult.OK)
                    if not ok and eng.local_free_tokens(req) <= 0:
                        # The next append would breach the quota and no
                        # creditor can absorb blocks: the cluster is out
                        # of pooled memory -> fail loudly, never corrupt
                        # (paper: reject when pool exhausted).
                        eng._fail(req)

    def _pick_creditor(self, exclude) -> Optional[int]:
        excl = {exclude} if isinstance(exclude, int) else set(exclude)
        best, best_free = None, 0
        for i, e in self.engines.items():
            if i in excl or i in self._dead:
                continue
            free = e.rmanager.effective_free
            if free > best_free:
                best, best_free = i, free
        return best

    # --- fault tolerance ------------------------------------------------#
    def kill_instance(self, inst_id: int) -> None:
        """Simulate an instance failure (stops heartbeating)."""
        self._dead.add(inst_id)

    def install_faults(self, plan: FaultPlan) -> FaultInjector:
        """Arm a deterministic chaos plan against this cluster.

        Crash/silence events fire at the top of the matching ``step()``;
        transfer faults (move leg, host fetch/corrupt, stager timeout)
        become one-shot armed flags the subsystem hooks consume on the
        next matching transfer. Returns the attached injector."""
        return FaultInjector(plan).attach(self)

    def _recover_via_replay(self, req: Request,
                            owner: Optional[InstanceEngine] = None) -> bool:
        """Re-admit one request whose KV (partially) died with a rank.

        Every surviving resource the request still holds is released
        exactly once — the live owner's slot + local blocks (when
        ``owner`` is given), hosted spans on live creditors, cache
        pins — then the request goes back to WAITING with
        ``needs_replay`` set: admission re-prefills ``prompt +
        output[:-1]`` (known tokens, NO resampling) and the next decode
        feeds ``output[-1]``, so the greedy continuation is
        byte-identical to an unfailed oracle. The emitted-token stream
        is never truncated — ``RequestHandle.tokens()`` consumers see
        no seam. A request past ``FaultPolicy.max_replays_per_request``
        FAILs instead of replaying forever. Returns True when the
        request was re-queued."""
        if req.done:
            return False
        rid = req.req_id
        if owner is not None:
            if req.slot is not None and \
                    owner.slots[req.slot] is req:
                owner.slots[req.slot] = None
            owner.rmanager.release_request(rid)
            owner.remote_insts.pop(rid, None)
            owner.req_chain.pop(rid, None)
        req.slot = None
        for i, e in self.engines.items():
            if i not in self._dead and e.rmanager.is_hosting(rid):
                e.drop_hosted(rid)
        if self.prefix_cache is not None:
            self.prefix_cache.release(rid)
        if req.output and \
                req.replays >= self.config.faults.max_replays_per_request:
            req.state = RequestState.FAILED
            req.finish_time = time.monotonic()
            self.fault_stats.failed_recoveries += 1
            return False
        req.state = RequestState.WAITING
        req.needs_replay = bool(req.output)
        self.fault_stats.recoveries += 1
        self.fault_stats.replayed_tokens += max(0, len(req.output) - 1)
        self.submit(req)
        return True

    def _handle_dead(self, dead: List[int]) -> None:
        """Quarantine newly dead instances and recover their requests.

        Every request with LOCAL blocks (owned by the dead engine) or a
        creditor-HOSTED span on the dead rank lost KV that is exactly
        recomputable from its known tokens — each is re-admitted via
        ``_recover_via_replay``. The dead rank's allocator is then
        drained wholesale (leftover records, cache replicas), so a
        quarantined rank — or, in global-pool mode, the quarantined
        slice of the one tensor — holds zero blocks, and the gManager
        forgets it: its view leaves Algorithm-1 planning and
        ``pick_instance_for_new_request`` can never choose it."""
        for d in dead:
            self._dead.add(d)
            self.fault_stats.dead_instances += 1
            eng = self.engines[d]
            # 1) Requests OWNED by the dead instance (running or queued):
            #    their local span is gone.
            for req in list(eng.running) + list(eng.waiting):
                self._recover_via_replay(req)
            eng.slots = [None] * eng.max_batch
            eng.waiting = []
            # 2) Requests owned by SURVIVORS with a span hosted on the
            #    dead rank: the lost creditor span is replayed too.
            for i, e in self.engines.items():
                if i in self._dead:
                    continue
                for req in list(e.running):
                    if d in e.remote_insts.get(req.req_id, ()):
                        self._recover_via_replay(req, owner=e)
            # 3) Drain the dead rank's allocator: whatever records
            #    remain (hosted spans of other dead-owned requests,
            #    stale entries) release here, and its prefix-cache
            #    replicas are purged — the quarantined rank ends with
            #    zero owned blocks.
            for rid in list(eng.rmanager.pool.requests):
                eng.rmanager.release_request(rid)
            if self.prefix_cache is not None:
                self.prefix_cache.purge_instance(d)
            eng.remote_insts.clear()
            eng.req_chain.clear()
            self.gmanager.deregister(d)

    def add_instance(self, params) -> int:
        """Elastic scale-out: new instance joins as a fresh creditor."""
        if self.gpool is not None:
            raise RuntimeError(
                "add_instance is unsupported in global-pool mode: the "
                "pool tensor's rank axis is fixed at construction")
        new_id = max(self.engines) + 1
        ref = next(iter(self.engines.values()))
        self.engines[new_id] = InstanceEngine(
            params, self.cfg, max_batch=ref.max_batch,
            max_local_len=ref.max_local_len,
            pool_blocks=ref.rmanager.pool.alloc.num_blocks,
            block_size=self.block_size, inst_id=new_id,
            prefill_chunk=ref.prefill_chunk)
        self.engines[new_id].prefix_sink = self._make_prefix_sink(new_id)
        self.engines[new_id].peers = self.engines
        if self.prefix_cache is not None:
            self._wire_cache(self.engines[new_id])
        self._need_full_hb.add(new_id)
        return new_id

    # ----------------------------------------------------------------- #
    def step(self, now: Optional[float] = None) -> int:
        """One cluster iteration: heartbeats, plan, moves, decode."""
        now = time.monotonic() if now is None else now
        self._step_count += 1

        # Armed chaos events fire first: a crash injected at this step
        # already misses this step's heartbeat, exactly like a real
        # failure in the gap between steps.
        if self.faults is not None:
            self.faults.on_step(self._step_count, self)

        # Heartbeats (dead and fault-silenced instances stay silent).
        beat: set = set()
        for i, eng in self.engines.items():
            if i in self._dead:
                continue
            if self.faults is not None and \
                    self.faults.silenced(i, self._step_count):
                continue
            full = i in self._need_full_hb or self.gmanager.bootstrapping
            ok = self.gmanager.on_heartbeat(eng.rmanager.heartbeat(full),
                                            now=now)
            if not ok:
                self.gmanager.on_heartbeat(
                    eng.rmanager.heartbeat(full=True), now=now)
            self._need_full_hb.discard(i)
            beat.add(i)
        self.gmanager.bootstrapping = False

        # Liveness: wall-clock timeout (back-compat) OR the
        # deterministic step-count detector (FaultPolicy).
        dead = self.gmanager.check_liveness(now=now)
        for d in self.gmanager.check_liveness_steps(beat):
            if d not in dead:
                dead.append(d)
        if dead:
            self._handle_dead(dead)

        # Reactive overflow shipping, then periodic Algorithm-1 planning.
        self._reactive_moves()
        if self._step_count % self.schedule_every == 0:
            # Frontend lifecycle feeds the planner: per-request urgency
            # (priority + deadline proximity) biases which debtor
            # requests are offloaded first, so near-deadline requests
            # get their memory relief before best-effort ones.
            urgency = {rid: r.urgency(now)
                       for rid, r in self.requests.items()
                       if not r.done and (r.priority
                                          or r.deadline_s is not None)}
            for mv in self.gmanager.plan_moves(urgency=urgency):
                self._execute_move(mv)

        # Resume parked (preempted) requests before the decode sweep so
        # a freed slot carries tokens this very step; the preemptor's
        # guards keep it from stealing capacity the waiting queue (or a
        # more urgent arrival) is entitled to.
        if self.preemptor is not None:
            self.preemptor.maybe_resume(now=now)

        made = 0
        for i, eng in self.engines.items():
            if i in self._dead:
                continue
            made += eng.step()
        if self.preemptor is not None:
            # Preempt-tier D2H spills finalize behind decode like the
            # shared tier's.
            self.preemptor.tier.drain(block=False)
        if self.host_tier is not None:
            # Finalize whichever D2H spills have landed — behind the
            # decode compute just dispatched, never blocking on it.
            self.host_tier.drain(block=False)
        # Free creditor-hosted blocks of requests that finished since the
        # last step (metadata only). Engines report each finish once.
        for i, eng in self.engines.items():
            if i not in self._dead:
                self._pending_release.update(eng.drain_finished())
        for rid in self._pending_release:
            req = self.requests.get(rid)
            if req is not None and not req.done:
                # A pause queues a finished event after dropping the
                # chain's hosted spans itself. If the request resumed
                # within this same step, is_hosting is true again for
                # its FRESH creditor spans — releasing those here would
                # silently shrink the resumed chain. Live requests keep
                # their spans; terminal ones release as usual.
                continue
            for eng in self.engines.values():
                if eng.rmanager.is_hosting(rid):
                    eng.drop_hosted(rid)
        self._pending_release.clear()
        return made

    # ----------------------------------------------------------------- #
    def run_until_done(self, max_steps: int = 10_000) -> int:
        """Step until every registered request is done; returns steps."""
        steps = 0
        while steps < max_steps and any(not r.done
                                        for r in self.requests.values()):
            self.step()
            steps += 1
        return steps

    @property
    def throughput_stats(self) -> Dict[str, float]:
        """Cluster-wide KV-moved / query-shipped byte counters."""
        total_kv = sum(e.stats.kv_moved for e in self.engines.values())
        total_q = sum(e.stats.query_shipped for e in self.engines.values())
        return {"kv_moved_bytes": total_kv, "query_shipped_bytes": total_q}
