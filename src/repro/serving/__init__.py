from repro.serving.cluster import Cluster
from repro.serving.engine import InstanceEngine
from repro.serving.gmanager import GManager
from repro.serving.kvpool import BlockAllocator, RankKVPool
from repro.serving.perfmodel import InstancePerfModel, cluster_tps
from repro.serving.request import Request, RequestState, SamplingParams
from repro.serving.rmanager import RManager
from repro.serving.scheduler import (GreedyScheduler, InstanceView,
                                     SpanLeg, StripedMove)

__all__ = [
    "Cluster", "InstanceEngine", "GManager", "BlockAllocator", "RankKVPool",
    "InstancePerfModel", "cluster_tps", "Request", "RequestState",
    "SamplingParams", "RManager", "GreedyScheduler", "InstanceView",
    "SpanLeg", "StripedMove",
]
