"""Infinite-LLM serving runtime: request-lifecycle frontend over a
DistAttention cluster.

Public API (start here)
-----------------------
``LLMServer`` is the serving frontend — the API everything outside this
package uses:

    from repro.serving import LLMServer, ServingConfig, SamplingParams

    server = LLMServer(params, cfg, ServingConfig.smoke(n_instances=3))
    handle = server.submit(prompt_tokens,
                           SamplingParams(max_new_tokens=32),
                           priority=1, deadline_s=2.0)
    for tok in handle.tokens():      # incremental stream
        ...
    handle.result(); handle.status; handle.metrics; handle.cancel()

    stats = server.run(arrivals)     # open-loop trace pump:
    stats["ttft_p99"], stats["tbt_p99"]

``ServingConfig`` is the one typed, frozen home of every serving knob
(cluster shape, KV pool, movement, Algorithm-1 thresholds, admission
backpressure), with ``smoke()``/``v5e()`` presets. Cancellation
propagates through every layer: engine slot, in-flight streaming
prefill (creditor reservations rolled back via the all-or-nothing
machinery), hosted spans, and planned KV moves (-> ``MoveResult.GONE``).

Internal layers (exported for tests/benchmarks, not the serving API)
--------------------------------------------------------------------
``Cluster`` executes steps: N ``InstanceEngine``s (each owning a
device-resident paged KV pool addressed through ``RankKVPool`` block
tables) plus a ``GManager`` running the paper's Algorithm 1 via
``GreedyScheduler``. Driving ``cluster.step()`` by hand is the OLD
batch-mode pattern — new code should go through ``LLMServer``.
"""
from repro.serving.cluster import Cluster
from repro.serving.config import ServingConfig
from repro.serving.engine import InstanceEngine
from repro.serving.gmanager import GManager
from repro.serving.kvpool import BlockAllocator, RankKVPool
from repro.serving.perfmodel import InstancePerfModel, cluster_tps
from repro.serving.request import (Request, RequestIdAllocator,
                                   RequestState, SamplingParams)
from repro.serving.rmanager import RManager
from repro.serving.scheduler import (GreedyScheduler, InstanceView,
                                     SpanLeg, StripedMove)
from repro.serving.server import Arrival, LLMServer, RequestHandle

__all__ = [
    "LLMServer", "RequestHandle", "Arrival", "ServingConfig",
    "Cluster", "InstanceEngine", "GManager", "BlockAllocator", "RankKVPool",
    "InstancePerfModel", "cluster_tps", "Request", "RequestIdAllocator",
    "RequestState", "SamplingParams", "RManager", "GreedyScheduler",
    "InstanceView", "SpanLeg", "StripedMove",
]
