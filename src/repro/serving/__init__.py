"""Infinite-LLM serving runtime: request-lifecycle frontend over a
DistAttention cluster.

Public API (start here)
-----------------------
``LLMServer`` is the serving frontend — the API everything outside this
package uses:

    from repro.serving import LLMServer, ServingConfig, SamplingParams

    server = LLMServer(params, cfg, ServingConfig.smoke(n_instances=3))
    handle = server.submit(prompt_tokens,
                           SamplingParams(max_new_tokens=32),
                           priority=1, deadline_s=2.0)
    for tok in handle.tokens():      # incremental stream
        ...
    handle.result(); handle.status; handle.metrics; handle.cancel()

    stats = server.run(arrivals)     # open-loop trace pump:
    stats["ttft_p99"], stats["tbt_p99"]

``ServingConfig`` is the one typed, frozen home of every serving knob
(cluster shape, KV pool, movement, Algorithm-1 thresholds, admission
backpressure), with ``smoke()``/``v5e()`` presets. Cancellation
propagates through every layer: engine slot, in-flight streaming
prefill (creditor reservations rolled back via the all-or-nothing
machinery), hosted spans, and planned KV moves (-> ``MoveResult.GONE``).

Prefix caching + the host-DRAM KV tier (opt-in)
-----------------------------------------------
Two ``ServingConfig`` knobs extend the paper's device-pooled memory one
level down and across requests:

    ServingConfig.smoke(prefix_cache=True,     # radix prefix cache
                        host_tier_blocks=4096) # host-DRAM spill tier

With ``prefix_cache=True`` every finished request's full KV blocks are
adopted (zero-copy, refcounted) into a ``RadixPrefixCache`` — a radix
tree over content-hashed block chains. A later request walks its
longest cached prefix at admission, pins the matching frames, and
streams prefill only for the uncached tail; a full-prompt hit shares
all but the last block and copies that one (copy-on-write), so cached
and cold admissions emit byte-identical KV and therefore identical
tokens. With ``host_tier_blocks > 0`` cold replicas spill to a
``HostKVTier`` of host-memory frames (async D2H behind compute, LRU
watermarks) instead of being dropped, and a later hit prefetches them
back (H2D through the stager) — ``server.metrics`` surfaces occupancy,
hit tokens, and spill/prefetch bytes; ``bench_prefix_cache`` gates warm
TTFT >= 2x cold and prefetch stalls <= 0.1 in CI.

Overload survival (opt-in)
--------------------------
``ServingConfig(overload=OverloadPolicy(enabled=True))`` lets the
frontend PAUSE running requests instead of making deadline-urgent
arrivals wait out the queue:

    ServingConfig.smoke(overload=OverloadPolicy(enabled=True))

When the admission queue backs up with work more urgent than what is
running, the ``Preemptor`` ranks victims by SLO slack (deadline minus
the perf model's predicted finish, charged the spill+resume round trip
— no-deadline best-effort requests rank first), pauses the chosen
victim at a step boundary, and spills its KV chain byte-for-byte to a
dedicated pinned host tier; creditor spans are released exactly once
and a mid-prefill pause reuses the cancel path's exact rollback but
re-queues the request. Resume restores the frames through the paged
admission path — no re-prefill — so a resumed request's greedy tokens
are identical to an unpreempted run (CI-gated as
``preempt_token_identity``; ``bench_overload`` also gates >= 1.3x
deadline goodput over the queue-only baseline at 2x overload). The
``ArrivalEstimator`` EWMA replaces the static ``avg_new_req_len`` knob
in Algorithm-1 planning while the server runs. ``server.metrics``
surfaces ``preemptions`` / ``preempt_resumes`` / ``paused_now`` /
``arrival_rate_hz``; knobs live on ``OverloadPolicy`` (see
``docs/ARCHITECTURE.md`` for the full reference).

Mesh-sharded global KV pool (opt-in)
------------------------------------
``ServingConfig(global_pool=True)`` folds the per-instance pool tensors
into ONE cluster-wide ``GlobalKVPool`` array ``[ranks, L, NB, bs, K,
hd]`` whose rank axis can be sharded over a device mesh:

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    layout = ServeLayout(batch_axes=("data",), pool_axes=("data",))
    server = LLMServer(params, cfg,
                       ServingConfig.v5e(global_pool=True),
                       mesh=mesh, layout=layout)

Knobs: ``ServingConfig.global_pool`` turns the mode on;
``LLMServer(..., mesh=...)`` attaches the mesh (omit it for the
single-device vmap path — same math, no collectives); ``layout``
(``ServeLayout.pool_axes``) picks which mesh axes shard the rank axis
(``("data",)`` or ``("data", "model")``; n_instances must divide their
total size). Every engine's rManager then aliases its ``RankKVPool``
slice of the global allocator, decode/prefill run
``decode_step_global``/``prefill_chunk_global`` (per-rank paged
partials under ``shard_map``, LSE-merged with pmax/psum — queries are
broadcast, KV never moves), and ``StripedMove`` legs, streaming
creditor writes, and prefix-cache materialization become slice
assignments inside the one tensor (remote DMA under GSPMD). The
donated-buffer zero-copy discipline is unchanged and CI-gated
(``decode_pool_zero_copy``); ``bench_sharded_pool`` gates rank-scaling
throughput.

Fault tolerance (always on; chaos injection opt-in)
---------------------------------------------------
Pooled KV means one failed creditor rank can hold pieces of OTHER
instances' requests, so the runtime detects, quarantines, and recovers
deterministically. Detection: an instance that misses
``FaultPolicy.heartbeat_timeout_steps`` consecutive heartbeats (or the
wall-clock ``heartbeat_timeout``) is marked DEAD — its view leaves
Algorithm-1 planning, it can never be picked as a creditor or owner
again, and its allocator (a quarantined slice of the one tensor in
global-pool mode) is drained wholesale. Recovery is TOKEN REPLAY:
every request that lost KV on the dead rank re-admits through the
normal paged admission path, re-prefilling ``prompt + output[:-1]``
(its emitted tokens are known — no resampling), so the greedy
continuation is byte-identical to an unfailed oracle (CI-gated as
``recovery_token_identity`` in both pool modes). Transfers retry with
bounded exponential backoff; host-tier fetches are verified against
the content hash the frame was stored under (a corrupted frame raises
instead of poisoning decode, then falls back to replay); a move stripe
whose leg fails mid-execution rolls back exactly and re-plans against
surviving creditors. Chaos testing: build a seedable ``FaultPlan``
(crash / heartbeat silence / move-leg failure / host fetch error /
frame corruption / stager timeout, each fireable at a chosen step) and
arm it with ``cluster.install_faults(plan)``; ``server.metrics``
surfaces ``dead_instances`` / ``fault_recoveries`` /
``replayed_tokens`` / ``transfer_retries`` and friends. Knobs live on
``FaultPolicy`` (see ``docs/ARCHITECTURE.md``); ``bench_chaos`` gates
recovery identity and goodput-under-crash in CI.

Internal layers (exported for tests/benchmarks, not the serving API)
--------------------------------------------------------------------
``Cluster`` executes steps: N ``InstanceEngine``s (each owning a
device-resident paged KV pool addressed through ``RankKVPool`` block
tables) plus a ``GManager`` running the paper's Algorithm 1 via
``GreedyScheduler``. Driving ``cluster.step()`` by hand is the OLD
batch-mode pattern — new code should go through ``LLMServer``.
"""
from repro.serving.cluster import Cluster
from repro.serving.config import (FaultPolicy, OverloadPolicy,
                                  ServingConfig)
from repro.serving.engine import InstanceEngine
from repro.serving.faults import (FaultEvent, FaultInjector, FaultPlan,
                                  FaultStats, FrameCorruptionError,
                                  TransferError)
from repro.serving.gmanager import GManager
from repro.serving.globalpool import GlobalKVPool
from repro.serving.hosttier import HostKVTier
from repro.serving.kvpool import BlockAllocator, RankKVPool
from repro.serving.preempt import Preemptor, PreemptStats
from repro.serving.prefixcache import RadixPrefixCache
from repro.serving.perfmodel import InstancePerfModel, cluster_tps
from repro.serving.request import (Request, RequestIdAllocator,
                                   RequestState, SamplingParams)
from repro.serving.rmanager import RManager
from repro.serving.scheduler import (GreedyScheduler, InstanceView,
                                     SpanLeg, StripedMove)
from repro.serving.server import Arrival, LLMServer, RequestHandle

__all__ = [
    "LLMServer", "RequestHandle", "Arrival", "ServingConfig",
    "OverloadPolicy", "Preemptor", "PreemptStats",
    "Cluster", "InstanceEngine", "GManager", "BlockAllocator", "RankKVPool",
    "InstancePerfModel", "cluster_tps", "Request", "RequestIdAllocator",
    "RequestState", "SamplingParams", "RManager", "GreedyScheduler",
    "InstanceView", "SpanLeg", "StripedMove", "HostKVTier",
    "RadixPrefixCache", "GlobalKVPool",
    "FaultPolicy", "FaultPlan", "FaultEvent", "FaultInjector",
    "FaultStats", "TransferError", "FrameCorruptionError",
]
