"""Overload survival: preemptive pause/host-spill scheduling.

Under sustained overload the admission queue alone starves
deadline-critical arrivals: every slot and pool block is held by
already-running (possibly long-context, best-effort) requests, and the
debtor/creditor machinery only moves memory BETWEEN instances — it
cannot make room that does not exist. Medha-style preemption does: the
``Preemptor`` stops a running request at a step boundary, spills its
whole KV chain (local blocks AND creditor-hosted spans, in token
order) byte-for-byte into a dedicated pinned ``HostKVTier``, and
releases every device resource it held — the slot, the local blocks,
the cache pins, and the creditor spans (through the same
finished-event / ``drop_hosted`` discipline every terminal path uses,
exactly once). The request itself survives as ``PAUSED`` with its
prompt/output/stream state intact.

Resume is re-admission through the paged path WITHOUT recompute: the
preemptor reserves a fresh placement (local tail blocks; overflow
striped onto creditors via the reserve-then-stream ``prefix_sink``),
uploads the saved frames H2D into the reserved blocks, and re-installs
the request in a slot — the next decode step feeds ``output[-1]`` over
byte-identical KV, so a preempted-then-resumed request emits exactly
the tokens an unpreempted oracle would (the bench_overload correctness
gate, in both per-instance and global-pool modes).

Victim selection is SLO-aware (``GreedyScheduler.victim_slack_s``):
slack = deadline - now - predicted finish (Eq. 5-7 over the gManager's
heartbeat views), charged the spill+resume round-trip
(``t_preempt_roundtrip``). Only victims whose charged slack stays
above ``OverloadPolicy.victim_min_slack_s`` — no-deadline requests
have infinite slack and go first — are paused, and only for queued
requests that out-rank them, so heavy-tail overload degrades the
slackest requests first and p99-critical ones last.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.serving.config import OverloadPolicy
from repro.serving.faults import FrameCorruptionError, TransferError
from repro.serving.hosttier import HostKVTier
from repro.serving.request import Request, RequestState


@dataclass
class PreemptStats:
    """Counters for the pause/spill/resume lifecycle."""

    preemptions: int = 0         # successful pauses
    resumes: int = 0             # successful resumes
    failed_pauses: int = 0       # refused (tier full / not pausable)
    failed_resumes: int = 0      # attempted but no capacity yet
    spilled_blocks: int = 0      # frames written to the preempt tier
    fetched_blocks: int = 0      # frames uploaded back on resume
    spilled_tokens: int = 0      # resident KV tokens across pauses


@dataclass
class _PausedRecord:
    """Book-keeping for one parked request: how much KV its host-tier
    frames hold (``(req_id, i)`` keys, chain order) and when it was
    paused (anti-thrash / resume ordering)."""

    req: Request
    n_tokens: int
    n_frames: int
    paused_at: float
    # Remote span layout of the chain at pause, chain order, as
    # (inst_id, n_blocks) runs. Resume reproduces this local/creditor
    # partition so the LSE-merge grouping — and therefore the greedy
    # argmax — matches the unpreempted run exactly, not just up to
    # reduction-order float drift.
    remote_layout: List[Tuple[int, int]]


class Preemptor:
    """Pause/spill/resume orchestrator over a cluster's engines.

    Owns a DEDICATED ``HostKVTier`` (``preempt_host_blocks`` frames,
    all pinned while their request is parked) separate from the prefix
    cache's tier: paused KV must always be resumable, so it never
    competes with cache watermark eviction. The frontend drives policy
    (``pause_for`` when urgent arrivals lack slots); ``maybe_resume``
    runs inside every cluster step and re-admits parked requests as
    capacity frees up — most urgent first, never stealing capacity a
    more urgent queued request (``queue_pressure``) is waiting for.
    """

    def __init__(self, cluster, policy: OverloadPolicy):
        self.cluster = cluster
        self.policy = policy
        # Watermarks at 1.0: eviction never runs below hard capacity —
        # every resident frame is pinned anyway while its request is
        # paused, so LRU pressure has nothing it may legally evict.
        fpol = cluster.config.faults
        self.tier = HostKVTier(policy.preempt_host_blocks,
                               high_watermark=1.0, low_watermark=1.0,
                               verify=fpol.verify_host_frames,
                               max_retries=fpol.max_transfer_retries,
                               backoff_base_s=fpol.retry_backoff_base_s,
                               backoff_max_s=fpol.retry_backoff_max_s)
        self.paused: Dict[int, _PausedRecord] = {}
        self.stats = PreemptStats()
        # Best urgency among the frontend's still-queued requests (set
        # by the server each step; None = no queue). A parked request
        # only resumes if it out-ranks this — otherwise the freed
        # capacity belongs to the queue and resuming would just get it
        # preempted again (thrash).
        self.queue_pressure: Optional[float] = None

    # --- pause --------------------------------------------------------- #
    def is_paused(self, req_id: int) -> bool:
        """True while ``req_id`` is parked in the preempt tier."""
        return req_id in self.paused

    def _live_engines(self):
        cl = self.cluster
        return [e for i, e in cl.engines.items() if i not in cl._dead]

    def _owner_of(self, req: Request):
        if req.slot is None:
            return None
        for eng in self._live_engines():
            if req.slot < len(eng.slots) and \
                    eng.slots[req.slot] is req:
                return eng
        return None

    def pause(self, req: Request, now: Optional[float] = None) -> bool:
        """Stop a RUNNING request at this step boundary and spill its
        KV chain to the preempt tier.

        All-or-nothing: the chain's frames are read (cross-engine for
        creditor spans) and stored/pinned BEFORE any device state is
        released; a tier without room refuses the pause and the request
        keeps running untouched. On success the owner releases the
        slot/blocks/cache pins and every creditor-hosted span is
        dropped exactly once (immediately here; the finished-event
        drain at step end sees ``is_hosting`` false and no-ops).
        Returns True when the request is now PAUSED."""
        now = time.monotonic() if now is None else now
        rid = req.req_id
        owner = self._owner_of(req)
        if (owner is None or req.state is not RequestState.RUNNING
                or req.cancelled or rid in self.paused
                or not owner._can_pool):
            self.stats.failed_pauses += 1
            return False
        got = owner.read_chain_frames(req)
        if got is None:
            self.stats.failed_pauses += 1
            return False
        n_tokens, frames = got
        # Record the chain's creditor runs (chain order) so resume can
        # reproduce the exact local/remote partition.
        remote_layout: List[List[int]] = []
        for inst, _b in owner.chain_of(req):
            if inst == owner.inst_id:
                continue
            if remote_layout and remote_layout[-1][0] == inst:
                remote_layout[-1][1] += 1
            else:
                remote_layout.append([inst, 1])
        if self.tier.free_blocks < len(frames):
            self.stats.failed_pauses += 1
            return False
        # Tag the spill on the cluster's stager: the D2H chain overlaps
        # decode like every other movement, bounded by the same double
        # buffer ("preempt_spill" gets its own stall counters).
        self.cluster.stager.stage(frames[-1], tag="preempt_spill")
        for i, (k, v) in enumerate(frames):
            ok = self.tier.put((rid, i), k, v)
            assert ok, "preempt tier refused despite free_blocks check"
            self.tier.pin((rid, i))
        owner.finalize_pause(req, now=now)
        for eng in self._live_engines():
            if eng.rmanager.is_hosting(rid):
                eng.drop_hosted(rid)
        self.paused[rid] = _PausedRecord(
            req, n_tokens, len(frames), now,
            [(i, n) for i, n in remote_layout])
        self.stats.preemptions += 1
        self.stats.spilled_blocks += len(frames)
        self.stats.spilled_tokens += n_tokens
        return True

    # --- SLO-aware victim selection ------------------------------------ #
    def rank_victims(self, now: float) -> List[Tuple[float, Request]]:
        """Preemption candidates as ``(slack_s, request)``, most
        preemptible first (largest charged slack, then cheapest spill).

        Built from the gManager's heartbeat views: per-instance
        batch/lengths feed the Eq. 5-7 predicted-finish, and each
        candidate's slack is charged its own spill+resume round trip
        (``victim_slack_s``). Requests out of pause budget
        (``max_preemptions``), about to finish, or whose chain could
        not be re-placed on resume (a spanning chain needs a creditor)
        are not candidates."""
        cl = self.cluster
        sched = cl.gmanager.scheduler
        views = {v.inst_id: v for v in cl.gmanager._views()}
        live = self._live_engines()
        out: List[Tuple[float, int, Request]] = []
        for eng in live:
            if not eng._can_pool:
                continue
            view = views.get(eng.inst_id)
            if view is None:
                continue
            bs = eng.block_size
            for r in eng.running:
                if (r.state is not RequestState.RUNNING or r.cancelled
                        or r.preemptions >= self.policy.max_preemptions):
                    continue
                remaining = r.sampling.max_new_tokens - len(r.output)
                if remaining <= 0:
                    continue
                rb = eng.rmanager.pool.requests.get(r.req_id)
                chain = eng.chain_of(r)
                if rb is None or not chain:
                    continue
                resident = (len(chain) - 1) * bs + rb.tail_tokens
                # A chain too long to sit locally resumes via creditor
                # striping — infeasible with no other live instance.
                if resident > eng.max_local_len - bs and len(live) < 2:
                    continue
                slack = sched.victim_slack_s(view, resident, remaining,
                                             r.deadline_at, now)
                out.append((slack, resident, r))
        out.sort(key=lambda t: (-t[0], t[1]))
        return [(s, r) for s, _, r in out]

    def pause_for(self, queued: Request,
                  now: Optional[float] = None) -> Optional[int]:
        """Free one slot for ``queued`` by pausing the best victim.

        A victim is eligible only when the queued request out-ranks it
        (``urgency``: priority strictly dominates, then deadline
        proximity) AND its charged slack stays above
        ``victim_min_slack_s`` — the victim is still expected to meet
        its own SLO after the detour. Returns the instance id whose
        slot was freed (so the caller can dispatch ``queued`` straight
        into it), or None when no victim is eligible."""
        now = time.monotonic() if now is None else now
        qu = queued.urgency(now)
        for slack, victim in self.rank_victims(now):
            if slack < self.policy.victim_min_slack_s:
                continue
            if qu <= victim.urgency(now):
                continue
            owner = self._owner_of(victim)
            if owner is not None and self.pause(victim, now=now):
                return owner.inst_id
        return None

    # --- resume -------------------------------------------------------- #
    def _resume_one(self, rec: _PausedRecord) -> bool:
        """Try to re-admit one parked request on some live engine."""
        req, rid = rec.req, rec.req.req_id
        frames = []
        try:
            for i in range(rec.n_frames):
                f = self.tier.get((rid, i))
                assert f is not None, "pinned preempt frame evicted"
                frames.append(f)
        except (TransferError, FrameCorruptionError):
            # A parked frame that cannot be fetched (or fails hash
            # verification) makes a byte-identical restore impossible —
            # fall back to token-replay recovery: drop the record and
            # re-admit via re-prefill of the known tokens.
            for i in range(rec.n_frames):
                self.tier.drop((rid, i))
            self.paused.pop(rid, None)
            self.stats.failed_resumes += 1
            self.cluster._recover_via_replay(req)
            return False
        # Engines with spare capacity first; never steal a slot an
        # already-dispatched (engine-waiting) request is about to take.
        cands = [e for e in self._live_engines()
                 if e._can_pool and not e.waiting
                 and e._free_slot() is not None]
        cands.sort(key=lambda e: -e.rmanager.effective_free)
        for eng in cands:
            if eng.resume_paused(req, rec.n_tokens, frames,
                                 remote_layout=rec.remote_layout):
                self.cluster.stager.stage((eng.pool_k, eng.pool_v),
                                          tag="preempt_fetch")
                for i in range(rec.n_frames):
                    self.tier.drop((rid, i))
                self.paused.pop(rid, None)
                self.stats.resumes += 1
                self.stats.fetched_blocks += rec.n_frames
                return True
        self.stats.failed_resumes += 1
        return False

    def maybe_resume(self, now: Optional[float] = None) -> int:
        """Resume parked requests that capacity (and the queue) allows.

        Called once per cluster step: most urgent first, oldest pause
        as the tie-break; a record younger than ``min_pause_s`` or
        out-ranked by ``queue_pressure`` stays parked. Returns how many
        requests were resumed."""
        if not self.paused:
            return 0
        now = time.monotonic() if now is None else now
        made = 0
        order = sorted(self.paused.values(),
                       key=lambda rec: (-rec.req.urgency(now),
                                        rec.paused_at))
        for rec in order:
            if rec.req.cancelled:
                self.cancel_paused(rec.req.req_id)
                continue
            if now - rec.paused_at < self.policy.min_pause_s:
                continue
            if self.queue_pressure is not None and \
                    rec.req.urgency(now) < self.queue_pressure:
                continue
            if self._resume_one(rec):
                made += 1
        return made

    # --- terminal path -------------------------------------------------- #
    def cancel_paused(self, req_id: int) -> bool:
        """Cancel a PARKED request: drop its tier frames and retire it
        terminally (device state was already released at pause)."""
        rec = self.paused.pop(req_id, None)
        if rec is None:
            return False
        for i in range(rec.n_frames):
            self.tier.drop((req_id, i))
        req = rec.req
        req.cancelled = True
        req.state = RequestState.CANCELLED
        req.finish_time = time.monotonic()
        return True


__all__ = ["Preemptor", "PreemptStats"]
