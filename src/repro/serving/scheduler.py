"""Cluster-level DistAttention scheduling — the paper's Algorithm 1.

Greedy debtor/creditor pairing driven by the Eq. 5-7 performance model:
debtors = instances with small batch (big marginal gain from freeing
memory), creditors = instances with low memory utilization. For each
debtor (ascending batch size), take its longest request and move the
modeled-optimal number of KV blocks to the emptiest creditor, repeating
until no move improves modeled aggregate throughput.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.serving.perfmodel import InstancePerfModel


@dataclass
class InstanceView:
    """Scheduler's (possibly stale — heartbeat-fed) view of one instance."""
    inst_id: int
    batch_size: int
    mem_blocks_total: int
    mem_blocks_used: int
    # req_id -> (total_len_tokens, local_blocks_here, is_owner)
    requests: Dict[int, Tuple[int, int, bool]] = field(default_factory=dict)
    offloaded_tokens: int = 0          # owner's KV held remotely
    hosted_tokens: int = 0             # others' KV held here
    alive: bool = True

    @property
    def mem_util(self) -> float:
        return self.mem_blocks_used / max(1, self.mem_blocks_total)


@dataclass
class MoveDecision:
    req_id: int
    src: int
    dst: int
    num_blocks: int


class GreedyScheduler:
    """Algorithm 1. Thresholds are the paper's beta^thres / U^thres."""

    def __init__(self, perf: InstancePerfModel, block_size: int,
                 beta_thres: int = 64, mem_util_thres: float = 0.8,
                 max_moves_per_round: int = 64,
                 avg_new_req_len: int = 512):
        self.perf = perf
        self.bs = block_size
        self.beta_thres = beta_thres
        self.mem_util_thres = mem_util_thres
        self.max_moves = max_moves_per_round
        # Typical length of a newly-admitted request — in deployment the
        # gManager estimates this from the recent arrival stream; it sets
        # how much batch growth a freed block buys (paper Fig. 7a slope).
        self.avg_new_len = avg_new_req_len

    # ------------------------------------------------------------------ #
    def _inst_tps(self, v: InstanceView) -> float:
        lengths = [ln for (ln, _, own) in v.requests.values() if own]
        return self.perf.tps(v.batch_size, lengths,
                             offloaded_tokens=v.offloaded_tokens,
                             hosted_tokens=v.hosted_tokens)

    def _pair_gain(self, d: InstanceView, c: InstanceView, req_id: int,
                   k_blocks: int) -> float:
        """Modeled aggregate TPS delta of moving k blocks d->c (Eq. 6/7).

        Freed debtor memory admits waiting work: model batch growth as one
        extra running request per freed block's worth of a median request
        is too aggressive; we conservatively credit only the KV-time saved
        plus batch growth when the debtor was memory-capped (batch grows
        by freed_tokens / avg_len).
        """
        tok = k_blocks * self.bs
        base = self._inst_tps(d) + self._inst_tps(c)
        own_lens = [ln for (ln, _, o) in d.requests.values() if o]
        avg_len = self.avg_new_len
        # Batch growth saturates at the compute roofline (the paper's
        # Fig. 2(b) plateau), not at the debtor-selection threshold.
        beta_sat = int(self.perf.hw.critical_intensity)
        extra_batch = min(tok // avg_len,
                          max(0, beta_sat - d.batch_size))
        d_new = self.perf.tps(d.batch_size + extra_batch,
                              own_lens + [avg_len] * extra_batch,
                              offloaded_tokens=d.offloaded_tokens + tok,
                              hosted_tokens=d.hosted_tokens)
        c_lens = [ln for (ln, _, o) in c.requests.values() if o]
        c_new = self.perf.tps(c.batch_size, c_lens,
                              offloaded_tokens=c.offloaded_tokens,
                              hosted_tokens=c.hosted_tokens + tok)
        return (d_new + c_new) - base

    # ------------------------------------------------------------------ #
    def plan(self, views: List[InstanceView]) -> List[MoveDecision]:
        views = [v for v in views if v.alive]
        debtors = sorted([v for v in views
                          if v.batch_size <= self.beta_thres],
                         key=lambda v: v.batch_size)
        creditors = sorted([v for v in views
                            if v.mem_util <= self.mem_util_thres],
                           key=lambda v: v.mem_util)
        # An instance never acts as both (paper §5.2).
        debtor_ids = {d.inst_id for d in debtors}
        creditors = [c for c in creditors if c.inst_id not in debtor_ids]

        moves: List[MoveDecision] = []
        for d in debtors:
            if not d.requests or len(moves) >= self.max_moves:
                continue
            # Longest owned request on the debtor.
            owned = [(rid, ln, blk) for rid, (ln, blk, own)
                     in d.requests.items() if own and blk > 1]
            if not owned:
                continue
            rid, rlen, rblocks = max(owned, key=lambda t: t[1])
            block_budget = rblocks - 1          # keep the live tail local
            for c in creditors:
                if block_budget <= 0 or len(moves) >= self.max_moves:
                    break
                free_blocks = (c.mem_blocks_total - c.mem_blocks_used)
                cap = min(block_budget, free_blocks)
                if cap <= 0:
                    continue
                # Search k in (0, cap] for the best modeled gain.
                best_k, best_gain = 0, 0.0
                step = max(1, cap // 16)
                for k in range(step, cap + 1, step):
                    g = self._pair_gain(d, c, rid, k)
                    if g > best_gain:
                        best_k, best_gain = k, g
                if best_k <= 0:
                    break                        # no gain from this debtor
                moves.append(MoveDecision(rid, d.inst_id, c.inst_id, best_k))
                # Update the views so later decisions see the effect.
                tok = best_k * self.bs
                d.offloaded_tokens += tok
                d.mem_blocks_used -= best_k
                ln, blk, own = d.requests[rid]
                d.requests[rid] = (ln, blk - best_k, own)
                c.hosted_tokens += tok
                c.mem_blocks_used += best_k
                block_budget -= best_k
            creditors.sort(key=lambda v: v.mem_util)
        return moves
