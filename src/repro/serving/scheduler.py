"""Cluster-level DistAttention scheduling — the paper's Algorithm 1,
generalized to STRIPED span plans.

Greedy debtor/creditor pairing driven by the Eq. 5-7 performance model:
debtors = instances with small batch (big marginal gain from freeing
memory), creditors = instances with low memory utilization. For each
debtor (ascending batch size), take its longest request and place its
movable prefix across one or MORE creditors: the planner searches the
TOTAL moved-block count over the combined capacity of up to
``max_stripes`` creditors, splits each candidate total greedily into
per-(creditor, k-blocks) legs (emptiest creditor first), and scores the
whole striped placement at once — per-leg marginal gains would miss
moves that only pay off past one creditor's capacity, which is exactly
the striping case. A request whose prefix exceeds any single creditor's
free blocks thus stripes across several, turning the per-instance pools
into the paper's cluster-wide memory pool. Each stripe is charged its
per-step query/merge traffic (``InstancePerfModel.t_span_merge``) and
credited its share of the parallel remote-slice speedup, so more
creditors is a modeled trade-off, never free.

Striped-plan protocol
---------------------
``plan()`` returns ``StripedMove``s: one source instance, one request,
and an ordered list of ``SpanLeg``s (destination, whole blocks). The
runtime must execute a plan all-or-nothing: reserve every leg on its
destination first (try_move_kvcache, FCFS), roll every reservation back
if any leg is refused, and only then copy pool rows + edit tables.
Legs of one plan never repeat a destination and never over-commit a
destination's free blocks as seen in the heartbeat views.

Reclaim path
------------
A creditor that itself becomes memory-stressed (its utilization rises
past the threshold, or it turns into a debtor while hosting others'
spans) is relieved symmetrically: ``plan()`` emits reclaim
``StripedMove``s that evict hosted spans BACK to their owner (when the
owner has headroom again) or SIDEWAYS to other creditors, again
all-or-nothing per plan.

``plan()`` never mutates the caller's views — it works on copies, so a
``GManager`` can re-plan from the same heartbeat state.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

from repro.serving.perfmodel import InstancePerfModel


@dataclass
class InstanceView:
    """Scheduler's (possibly stale — heartbeat-fed) view of one instance."""
    inst_id: int
    batch_size: int
    mem_blocks_total: int
    mem_blocks_used: int
    # req_id -> (total_len_tokens, local_blocks_here, is_owner)
    requests: Dict[int, Tuple[int, int, bool]] = field(default_factory=dict)
    offloaded_tokens: int = 0          # owner's KV held remotely
    hosted_tokens: int = 0             # others' KV held here
    alive: bool = True
    # Unpinned prefix-cache replicas: blocks that count in
    # mem_blocks_used but are reclaimable on demand (evicted/spilled to
    # the host tier). Algorithm 1 treats them as creditor capacity,
    # charged a spill-cost penalty when a plan would displace them.
    cache_blocks: int = 0
    # Owned requests' creditor spans: req_id -> {creditor_inst: blocks}.
    # Populated by GManager._views from the cross-instance placement map;
    # drives the per-span merge-cost and parallel-slice terms.
    req_spans: Dict[int, Dict[int, int]] = field(default_factory=dict)

    @property
    def mem_util(self) -> float:
        """Fraction of this instance's pool blocks in use."""
        return self.mem_blocks_used / max(1, self.mem_blocks_total)

    @property
    def free_blocks(self) -> int:
        """Unused pool blocks in this view."""
        return self.mem_blocks_total - self.mem_blocks_used

    def copy(self) -> "InstanceView":
        """Deep-enough copy: planning mutates it, heartbeats stay pristine."""
        return replace(
            self, requests=dict(self.requests),
            req_spans={r: dict(s) for r, s in self.req_spans.items()})


@dataclass
class SpanLeg:
    """One stripe of a striped plan: whole blocks onto one destination."""
    dst: int
    num_blocks: int


@dataclass
class StripedMove:
    """One all-or-nothing planned movement of a request's KV blocks.

    ``kind`` is "offload" (debtor -> creditors) or "reclaim" (a stressed
    creditor evicts a hosted span back to its owner / sideways).
    """
    req_id: int
    src: int
    legs: List[SpanLeg]
    kind: str = "offload"

    @property
    def num_blocks(self) -> int:
        """Total blocks this striped move transfers."""
        return sum(leg.num_blocks for leg in self.legs)


# Backwards-compatible alias: a single-leg plan is the old MoveDecision.
MoveDecision = StripedMove


class GreedyScheduler:
    """Algorithm 1 with striped spans. Thresholds are the paper's
    beta^thres / U^thres; ``max_stripes`` caps how many creditors one
    request's plan may fan out to per round (1 = the paper's original
    single-creditor greedy)."""

    def __init__(self, perf: InstancePerfModel, block_size: int,
                 beta_thres: int = 64, mem_util_thres: float = 0.8,
                 max_moves_per_round: int = 64,
                 avg_new_req_len: int = 512,
                 max_stripes: int = 8,
                 reclaim_horizon_s: float = 1.0):
        self.perf = perf
        self.bs = block_size
        self.beta_thres = beta_thres
        self.mem_util_thres = mem_util_thres
        self.max_moves = max_moves_per_round
        self.max_stripes = max_stripes
        # Typical length of a newly-admitted request. The config value
        # is only the PRIOR: the gManager's EWMA ``ArrivalEstimator``
        # overwrites it from the live arrival stream before every
        # planning round, so the batch-growth credit (paper Fig. 7a
        # slope) tracks the traffic actually hitting the cluster.
        self.avg_new_len = avg_new_req_len
        # EWMA arrival rate (req/s) from the same estimator; 0 means
        # "unknown" (no frontend feeding us) and disables the cap below.
        self.arrival_rate_hz = 0.0
        # Amortization window of the reclaim gain check: undoing a
        # stripe must win back its own movement cost within this many
        # seconds of modeled decode, or the eviction is not planned.
        self.reclaim_horizon_s = reclaim_horizon_s

    # ------------------------------------------------------------------ #
    def _span_stats(self, v: InstanceView) -> Tuple[int, int]:
        """(span_entries, max single-creditor slice in tokens) of v."""
        entries = sum(len(s) for s in v.req_spans.values())
        mx = max((blk for s in v.req_spans.values()
                  for blk in s.values()), default=0)
        return entries, mx * self.bs

    def _inst_tps(self, v: InstanceView) -> float:
        lengths = [ln for (ln, _, own) in v.requests.values() if own]
        entries, mx = self._span_stats(v)
        return self.perf.tps(v.batch_size, lengths,
                             offloaded_tokens=v.offloaded_tokens,
                             hosted_tokens=v.hosted_tokens,
                             span_entries=entries, max_span_tokens=mx)

    # --- SLO-aware preemption scoring --------------------------------- #
    def predicted_finish_s(self, v: InstanceView,
                           remaining_tokens: int) -> float:
        """Eq. 5-7 horizon: modeled seconds until a request running on
        instance ``v`` with ``remaining_tokens`` left to decode
        finishes, given v's current batch/lengths/spans."""
        lengths = [ln for (ln, _, own) in v.requests.values() if own]
        entries, _ = self._span_stats(v)
        return self.perf.predicted_finish_s(
            v.batch_size, lengths, remaining_tokens,
            offloaded_tokens=v.offloaded_tokens,
            hosted_tokens=v.hosted_tokens, span_entries=entries)

    def victim_slack_s(self, v: InstanceView, resident_tokens: int,
                       remaining_tokens: int,
                       deadline_at: Optional[float],
                       now: float) -> float:
        """SLO slack of a preemption candidate AFTER paying the pause.

        slack = deadline - now - predicted_finish - spill/resume cost
        (``t_preempt_roundtrip`` over ``resident_tokens`` of KV). A
        request without a deadline has infinite slack — the preferred
        victim. The preemptor only pauses candidates whose charged
        slack stays above ``OverloadPolicy.victim_min_slack_s``, so a
        victim is expected to STILL meet its own SLO after the detour;
        heavy-tail overload therefore degrades the slackest requests
        first and p99-critical ones last."""
        if deadline_at is None:
            return float("inf")
        return deadline_at - now \
            - self.predicted_finish_s(v, remaining_tokens) \
            - self.perf.t_preempt_roundtrip(resident_tokens)

    def _apply_leg(self, d: InstanceView, c: InstanceView, rid: int,
                   k_blocks: int) -> None:
        """Mutate working views as if k blocks of rid moved d -> c.

        Blocks beyond the creditor's plain free pool displace unpinned
        prefix-cache replicas (the runtime evicts/spills them on
        demand): those frames change hands rather than growing
        ``mem_blocks_used``."""
        tok = k_blocks * self.bs
        d.offloaded_tokens += tok
        d.mem_blocks_used -= k_blocks
        ln, blk, own = d.requests[rid]
        d.requests[rid] = (ln, blk - k_blocks, own)
        spans = d.req_spans.setdefault(rid, {})
        spans[c.inst_id] = spans.get(c.inst_id, 0) + k_blocks
        evicted = max(0, k_blocks - c.free_blocks)
        c.cache_blocks = max(0, c.cache_blocks - evicted)
        c.hosted_tokens += tok
        c.mem_blocks_used += k_blocks - evicted

    def _creditor_cap(self, c: InstanceView, *,
                      with_cache: bool = True) -> int:
        """Blocks an offload may place on creditor ``c``: its free
        blocks MINUS one block of headroom per running request, so the
        creditor's own decode tails can keep growing until the next
        planning round instead of hard-failing on pool exhaustion.

        Unpinned prefix-cache replicas (``cache_blocks``) count too —
        the runtime evicts or spills them on demand — but placements
        that dip into them are charged the host-link spill cost in
        ``_striped_gain``, so displacing a warm cache must pay."""
        extra = c.cache_blocks if with_cache else 0
        return max(0, c.free_blocks + extra - c.batch_size)

    def _split_blocks(self, k: int,
                      cands: List[InstanceView]) -> List[Tuple[int, int]]:
        """Greedy split of k blocks over candidate creditors (emptiest
        first, each filled to its headroom-capped capacity):
        [(creditor_idx, n)]."""
        splits = []
        for i, c in enumerate(cands):
            take = min(k, self._creditor_cap(c))
            if take > 0:
                splits.append((i, take))
                k -= take
            if k <= 0:
                break
        return splits

    def _debtor_tps_after(self, d2: InstanceView, base_batch: int,
                          moved_tok: int) -> float:
        """Debtor TPS after a plan, crediting batch growth: freed memory
        admits ~moved_tok / avg_new_len waiting requests, saturating at
        the compute roofline (the paper's Fig. 2(b) plateau), not at the
        debtor-selection threshold."""
        beta_sat = int(self.perf.hw.critical_intensity)
        extra = min(moved_tok // self.avg_new_len,
                    max(0, beta_sat - base_batch))
        # Freed memory only buys throughput if requests actually ARRIVE
        # to fill it: cap the credit by the EWMA-estimated arrivals
        # within the amortization horizon (unknown rate => uncapped,
        # the original optimistic behavior).
        if self.arrival_rate_hz > 0.0:
            expected = int(self.arrival_rate_hz *
                           self.reclaim_horizon_s) + 1
            extra = min(extra, expected)
        own_lens = [ln for (ln, _, o) in d2.requests.values() if o]
        entries, mx = self._span_stats(d2)
        return self.perf.tps(d2.batch_size + extra,
                             own_lens + [self.avg_new_len] * extra,
                             offloaded_tokens=d2.offloaded_tokens,
                             hosted_tokens=d2.hosted_tokens,
                             span_entries=entries, max_span_tokens=mx)

    def _striped_gain(self, d: InstanceView, cands: List[InstanceView],
                      rid: int, splits: List[Tuple[int, int]]) -> float:
        """Modeled aggregate TPS delta of applying a whole striped
        placement (every leg at once, Eq. 6/7 plus span merge cost)."""
        base = self._inst_tps(d) + sum(self._inst_tps(c) for c in cands)
        d2 = d.copy()
        c2s = {i: cands[i].copy() for i, _ in splits}
        for i, n in splits:
            self._apply_leg(d2, c2s[i], rid, n)
        tok = sum(n for _, n in splits) * self.bs
        d_new = self._debtor_tps_after(d2, d.batch_size, tok)
        after = d_new + sum(self._inst_tps(c2s.get(i, c))
                            for i, c in enumerate(cands))
        gain = after - base
        # Spill penalty: legs that overflow a creditor's plain headroom
        # displace unpinned cache replicas, whose frames must cross the
        # host link (D2H) before the leg's blocks land. Charged
        # un-overlapped and amortized over reclaim_horizon_s — the same
        # units as ``_reclaim_pays`` — so cache-displacing placements
        # only win when the freed-memory gain clearly beats re-warming.
        for i, n in splits:
            c = cands[i]
            overflow = min(n - self._creditor_cap(c, with_cache=False),
                           c.cache_blocks)
            if overflow > 0:
                t_spill = self.perf.t_host_transfer(overflow * self.bs)
                gain -= t_spill * self._inst_tps(c) / \
                    self.reclaim_horizon_s
        return gain

    def modeled_aggregate_tps(self, views: List[InstanceView],
                              moves: List[StripedMove]) -> float:
        """Aggregate modeled cluster TPS (Eq. 7) after applying
        ``moves`` to copies of ``views`` — the planner's own objective,
        batch-growth credit included. Public so benchmarks and monitors
        score plans with exactly the model the planner optimizes.
        Only offload moves are applied (reclaim application needs the
        owner-resolution bookkeeping internal to planning)."""
        work = {v.inst_id: v.copy() for v in views}
        moved_tok: Dict[int, int] = {}
        base_batch = {v.inst_id: v.batch_size for v in views}
        for mv in moves:
            if mv.kind != "offload":
                continue
            for leg in mv.legs:
                self._apply_leg(work[mv.src], work[leg.dst],
                                mv.req_id, leg.num_blocks)
                moved_tok[mv.src] = moved_tok.get(mv.src, 0) + \
                    leg.num_blocks * self.bs
        total = 0.0
        for iid, v in work.items():
            if iid in moved_tok:
                total += self._debtor_tps_after(v, base_batch[iid],
                                                moved_tok[iid])
            else:
                total += self._inst_tps(v)
        return total

    # ------------------------------------------------------------------ #
    def _plan_offloads(self, debtors: List[InstanceView],
                       creditors: List[InstanceView],
                       urgency: Dict[int, float]) -> List[StripedMove]:
        moves: List[StripedMove] = []
        for d in debtors:
            if not d.requests or len(moves) >= self.max_moves:
                continue
            # The debtor's most urgent owned request (frontend priority
            # + deadline proximity), length as the tie-break — without
            # lifecycle metadata this reduces to the original
            # longest-request pick.
            owned = [(rid, ln, blk) for rid, (ln, blk, own)
                     in d.requests.items() if own and blk > 1]
            if not owned:
                continue
            rid, _, rblocks = max(
                owned, key=lambda t: (urgency.get(t[0], 0.0), t[1]))
            block_budget = rblocks - 1          # keep the live tail local
            # Candidate creditors, emptiest first, capped at max_stripes
            # (headroom-capped: never fill a creditor past what leaves
            # its own running requests room to grow).
            cands = sorted((c for c in creditors
                            if self._creditor_cap(c) > 0),
                           key=lambda v: v.mem_util)[:self.max_stripes]
            cap_total = min(block_budget,
                            sum(self._creditor_cap(c) for c in cands))
            if cap_total <= 0:
                continue
            # Search the TOTAL moved-block count; each candidate total is
            # split greedily into per-(creditor, k) legs and the whole
            # striped placement is scored at once — per-leg marginal
            # gains miss moves that only pay off past one creditor's
            # capacity, which is exactly the striping case.
            best_splits, best_gain = None, 0.0
            step = max(1, cap_total // 16)
            for k in range(step, cap_total + 1, step):
                splits = self._split_blocks(k, cands)
                g = self._striped_gain(d, cands, rid, splits)
                if g > best_gain:
                    best_splits, best_gain = splits, g
            if not best_splits:
                continue
            for i, n in best_splits:
                self._apply_leg(d, cands[i], rid, n)
            moves.append(StripedMove(
                rid, d.inst_id,
                [SpanLeg(cands[i].inst_id, n) for i, n in best_splits]))
            creditors.sort(key=lambda v: v.mem_util)
        return moves

    def _apply_reclaim(self, by_id: Dict[int, InstanceView], host_id: int,
                       owner_id: Optional[int], rid: int, blk: int,
                       legs: List[SpanLeg]) -> None:
        """Mutate views as if host ``host_id`` evicted rid's ``blk``-block
        hosted span along ``legs`` (owner re-adopt and/or sideways)."""
        h = by_id[host_id]
        owner = by_id.get(owner_id) if owner_id is not None else None
        h.hosted_tokens -= blk * self.bs
        h.mem_blocks_used -= blk
        del h.requests[rid]
        for leg in legs:
            dst = by_id[leg.dst]
            dst.mem_blocks_used += leg.num_blocks
            if owner is not None and leg.dst == owner.inst_id:
                owner.offloaded_tokens -= leg.num_blocks * self.bs
                ln, b0, own = owner.requests[rid]
                owner.requests[rid] = (ln, b0 + leg.num_blocks, own)
            else:
                dst.hosted_tokens += leg.num_blocks * self.bs
            if owner is not None:
                spans = owner.req_spans.setdefault(rid, {})
                spans.pop(host_id, None)
                if leg.dst != owner.inst_id:
                    spans[leg.dst] = spans.get(leg.dst, 0) + \
                        leg.num_blocks

    def _reclaim_pays(self, by_id: Dict[int, InstanceView], host_id: int,
                      owner_id: Optional[int], rid: int, blk: int,
                      legs: List[SpanLeg]) -> bool:
        """Eq. 5-7 gain check for one reclaim candidate: undo a stripe
        only when the modeled aggregate tps gain, amortized over
        ``reclaim_horizon_s``, exceeds the movement cost.

        Gain is scored on copies of the involved views exactly like an
        offload plan — including the batch-growth credit of the host's
        freed blocks (relieving a stressed host is worth admitted work,
        not just lower utilization). Cost is the decode the source and
        destinations forgo while the span's bytes cross the link,
        charged UN-overlapped — conservative now that the runtime
        overlaps movement with compute, so marginal evictions stay
        filtered (the anti-thrash hysteresis) while clearly-paying ones
        pass."""
        involved = {host_id} | {leg.dst for leg in legs}
        if owner_id is not None:
            involved.add(owner_id)
        copies = {i: by_id[i].copy() for i in involved}
        before = sum(self._inst_tps(v) for v in copies.values())
        self._apply_reclaim(copies, host_id, owner_id, rid, blk, legs)
        freed_tok = blk * self.bs
        after = 0.0
        for i, v in copies.items():
            if i == host_id:
                after += self._debtor_tps_after(
                    v, by_id[i].batch_size, freed_tok)
            else:
                after += self._inst_tps(v)
        gain = after - before
        if gain <= 0.0:
            return False
        move_bytes = freed_tok * self.perf.kv_bytes_per_token_layer() \
            * self.perf.cfg.num_layers
        t_move = move_bytes / self.perf.hw.ici_link_bw
        busy = {host_id} | {leg.dst for leg in legs}
        lost_tokens = t_move * sum(self._inst_tps(by_id[i]) for i in busy)
        return gain * self.reclaim_horizon_s >= lost_tokens

    def _plan_reclaims(self, views: List[InstanceView],
                       stressed: List[InstanceView],
                       creditors: List[InstanceView]) -> List[StripedMove]:
        """Symmetric path: a memory-stressed host evicts hosted spans
        back to their owners (preferred) or sideways to calm creditors.

        Eviction stops as soon as the host is back under the creditor
        threshold — relief, not a purge — which together with the
        stress trigger sitting ABOVE that threshold (see ``plan``)
        gives the offload/reclaim pair a hysteresis band instead of a
        copy ping-pong at the margin. The trigger only NOMINATES spans:
        each candidate must additionally pass the ``_reclaim_pays``
        Eq. 5-7 gain-vs-move-cost check, so a stripe is undone only
        when reclaiming it is modeled to pay for its own copies."""
        by_id = {v.inst_id: v for v in views}
        moves: List[StripedMove] = []
        for h in stressed:
            hosted = [(rid, blk) for rid, (ln, blk, own)
                      in h.requests.items() if not own and blk > 0]
            if not hosted:
                continue
            # Evict the smallest spans first: cheapest relief per move.
            hosted.sort(key=lambda t: t[1])
            for rid, blk in hosted:
                if len(moves) >= self.max_moves or \
                        h.mem_util <= self.mem_util_thres:
                    break                # relieved — stop evicting
                owner = next((v for v in views
                              if v.requests.get(rid, (0, 0, False))[2]),
                             None)
                owner_id = owner.inst_id if owner is not None else None
                legs: List[SpanLeg] = []
                remaining = blk
                # Preferred: back to the owner if it has real headroom
                # (it must stay under the creditor threshold afterwards).
                if owner is not None and owner.inst_id != h.inst_id:
                    room = owner.free_blocks
                    after = (owner.mem_blocks_used + remaining) / \
                        max(1, owner.mem_blocks_total)
                    if room >= remaining and after <= self.mem_util_thres:
                        legs.append(SpanLeg(owner.inst_id, remaining))
                        remaining = 0
                # Sideways: stripe what's left across calm creditors.
                if remaining > 0:
                    for c in sorted(creditors, key=lambda v: v.mem_util):
                        if remaining <= 0 or \
                                len(legs) >= self.max_stripes:
                            break
                        if c.inst_id == h.inst_id or \
                                (owner is not None
                                 and c.inst_id == owner.inst_id):
                            continue
                        # Reclaims stay within plain free headroom: a
                        # relief move must not itself trash a cache.
                        take = min(remaining,
                                   self._creditor_cap(c, with_cache=False))
                        if take <= 0:
                            continue
                        legs.append(SpanLeg(c.inst_id, take))
                        remaining -= take
                if not legs or remaining > 0:
                    continue                 # nowhere to put the span
                if not self._reclaim_pays(by_id, h.inst_id, owner_id,
                                          rid, blk, legs):
                    continue                 # relief would cost > it gains
                self._apply_reclaim(by_id, h.inst_id, owner_id, rid, blk,
                                    legs)
                moves.append(StripedMove(rid, h.inst_id, legs,
                                         kind="reclaim"))
        return moves

    def plan(self, views: List[InstanceView],
             urgency: Optional[Dict[int, float]] = None
             ) -> List[StripedMove]:
        """One Algorithm-1 round: offload stressed debtors, reclaim
        stressed creditors; returns the striped move plans in order."""
        # Work on copies: the caller's heartbeat-fed views stay pristine
        # so the gManager can re-plan from the same state.
        urgency = urgency or {}
        views = [v.copy() for v in views if v.alive]
        # Quarantine hardening: a dead rank's view is excluded above,
        # and any STALE span entry naming a non-alive creditor is
        # stripped from the survivors' placement maps — it must not be
        # scored as a merge cost, a reclaim source, or a stripe target.
        alive_ids = {v.inst_id for v in views}
        for v in views:
            v.req_spans = {rid: kept
                           for rid, spans in v.req_spans.items()
                           if (kept := {i: b for i, b in spans.items()
                                        if i in alive_ids})}

        def inst_urgency(v: InstanceView) -> float:
            """Most urgent owned request on ``v`` (0 if none)."""
            return max((urgency.get(rid, 0.0)
                        for rid, (_, _, own) in v.requests.items()
                        if own), default=0.0)
        # A debtor must have something to offload: an idle instance with
        # no owned requests is a creditor candidate, not a debtor.
        # Near-deadline/high-priority debtors are planned first so they
        # get creditor capacity before best-effort ones exhaust it.
        debtors = sorted([v for v in views
                          if v.batch_size <= self.beta_thres
                          and any(own for (_, _, own)
                                  in v.requests.values())],
                         key=lambda v: (-inst_urgency(v), v.batch_size))
        creditors = sorted([v for v in views
                            if v.mem_util <= self.mem_util_thres],
                           key=lambda v: v.mem_util)
        # An instance never acts as both (paper §5.2).
        debtor_ids = {d.inst_id for d in debtors}
        creditors = [c for c in creditors if c.inst_id not in debtor_ids]
        # Reclaim first: hosts that crossed the STRESS threshold while
        # holding others' spans free their own headroom before new
        # offloads are planned onto the remaining creditors. The stress
        # trigger sits halfway between the creditor threshold and full:
        # an instance stops being a creditor at mem_util_thres but is
        # only force-relieved above this band (hysteresis against
        # offload/reclaim ping-pong right at the threshold).
        stress_thres = (self.mem_util_thres + 1.0) / 2
        stressed = [v for v in views
                    if v.hosted_tokens > 0
                    and v.mem_util > stress_thres]
        moves = self._plan_reclaims(views, stressed, creditors)
        creditors.sort(key=lambda v: v.mem_util)
        moves += self._plan_offloads(debtors, creditors, urgency)
        return moves[:self.max_moves]
