"""Single-instance serving engine: continuous batching over a paged pool.

ORCA-style iteration-level scheduling: each ``step()`` admits waiting
requests into free slots (prefill), then runs ONE decode iteration for
all running slots. For poolable families (dense/moe) ALL serving KV
bytes live in the instance's device-resident block pool
``pool_k/pool_v: [L, num_blocks, block_size, K, hd]``, managed by the
``RManager``'s block allocator and addressed only through block tables:

  * admission is STREAMING PAGED PREFILL: every block the prompt needs
    is reserved up front (the local tail in this pool; the overflow
    prefix committed on creditors through the reserve-then-stream
    ``prefix_sink``), then ``prefill_chunk_paged`` streams the prompt in
    fixed-shape chunks — chunk-internal causal attention plus paged
    MicroAttention partials over the already-written spans, with each
    chunk's KV rows scattered straight into the reserved blocks. No
    dense ``[L, 1, T, K, hd]`` cache is ever materialized: peak
    admission memory is O(chunk + pool) and a prompt can stripe its
    prefix across several creditors at admission time,
  * each decode step appends the new token's KV into the request's tail
    block inside the jitted ``decode_step_paged``,
  * creditor-hosted spans are just blocks owned by ``req_id`` in the
    creditor's pool (``host_kv`` writes whole migrated blocks;
    ``host_kv_rows`` takes the prefill stream's row-addressed writes;
    dropping them is a metadata release),
  * moving KV between instances copies pool rows and edits tables —
    shapes never change, so the decode step never retraces from growth;
    a striped Algorithm-1 plan is just a sequence of such copies, one
    per (destination, k-blocks) leg, each reserved before any byte
    moves. Whole blocks carry complete (position-encoded) KV rows, so
    cross-rank placement and within-rank block order are
    correctness-neutral — only the per-span merge traffic changes.

``max_local_len`` survives as the per-request LOCAL QUOTA (the paper's
instance-local budget): when a request's local span approaches it the
cluster ships prefix blocks to a creditor and decoding continues with
the multi-rank paged step. Non-attention families (hybrid/ssm) keep the
dense ``prefill()`` + ``DecodeState`` path — their recurrent state is
O(1) per request and never pools.

ZERO-COPY DISCIPLINE: the pool tensors (and the sampling PRNG key) are
DONATED into every jitted step and updater — each engine threads exactly
one live ``pool_k``/``pool_v`` (and ``_key``) reference functionally;
a handle passed into a step is dead afterwards and the returned array
is the same device buffer updated in place on donating backends.
``CommStats.pool_copy_steps`` counts the steps where that in-place
reuse did NOT happen (0 on the hot path; asserted by
tests/test_zero_copy.py and gated by bench_kv_movement's
``decode_pool_zero_copy`` metric).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import DecodeState, decode_step, init_decode_state
from repro.models.prefill import (decode_step_paged, prefill,
                                  prefill_chunk_paged, repack_ring,
                                  write_slot)
from repro.serving.sharded_step import (decode_step_global,
                                        prefill_chunk_global)
from repro.serving.kvpool import (build_local_tables, prefix_tables,
                                  read_pool_rows, rows_for_token_range,
                                  scatter_pool_rows, table_bucket,
                                  write_pool_rows)
from repro.serving.request import Request, RequestState
from repro.serving.rmanager import RManager


@dataclass
class CommStats:
    """Bytes moved, per category — feeds the Fig. 4/11/12 benchmarks."""
    kv_moved: int = 0            # KV block migration (overlapped)
    query_shipped: int = 0       # q + (o, m, l) merge traffic per step
    tokens_moved_steps: List[int] = field(default_factory=list)
    host_gather_s: float = 0.0   # host-side table/step-input build time
    decode_steps: int = 0
    # Decode steps whose jitted step COPIED the [L, NB, bs, K, hd] pool
    # instead of updating the donated buffer in place (0 on backends
    # that honor donation — the zero-copy hot path).
    pool_copy_steps: int = 0
    # Peak bytes of prompt-KV STAGED in flight by admission — the arrays
    # holding prompt KV outside the pools. Streaming admission stages one
    # chunk's [L, C, K, hd] export; the dense path stages the whole
    # [L, 1, T, K, hd] cache. (Per-layer attention workspace — scores,
    # prefix reads — is common to both paths and not counted.)
    admit_stage_bytes: int = 0
    # Host-tier traffic through this instance's pool: blocks spilled
    # D2H by prefix-cache eviction / prefetched H2D on a host-tier hit.
    host_spill_bytes: int = 0
    host_prefetch_bytes: int = 0
    # Prompt tokens admission covered from the prefix cache instead of
    # prefilling (the FLOPs the cache saved this instance).
    cache_hit_tokens: int = 0


def buffer_ptr(x) -> Optional[int]:
    """Device buffer address of a jax Array, or None when the backend
    does not expose one. Does NOT block on in-flight computations — the
    output buffer of a dispatched step is known before it is filled, so
    donation (buffer reuse) can be asserted without a sync point."""
    try:
        return x.unsafe_buffer_pointer()
    except Exception:
        return None


@functools.partial(jax.jit, donate_argnames=("key",))
def _sample_batch(key, logits, temps):
    """Next tokens for EVERY slot in one device call (one readback/step).

    The PRNG key is split DEVICE-SIDE and donated: the engine threads one
    live key through the steps the same way it threads the pool tensors —
    no per-step key re-upload, and the spent key's buffer is reused for
    its successor. logits [B, V], temps [B] -> ([B] int32, new key);
    temperature <= 0 is greedy.
    """
    key, sub = jax.random.split(key)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    safe_t = jnp.where(temps > 0, temps, 1.0)
    keys = jax.random.split(sub, logits.shape[0])
    sampled = jax.vmap(jax.random.categorical)(
        keys, logits.astype(jnp.float32) / safe_t[:, None])
    return jnp.where(temps > 0, sampled.astype(jnp.int32), greedy), key


@functools.partial(jax.jit, donate_argnames=("key",))
def _sample_batch_topk(key, logits, temps, top_ks):
    """``_sample_batch`` with a per-slot top-k filter: everything below
    each row's k-th largest logit is masked before sampling (k == 0
    keeps the full distribution). Separate jit so batches with no
    top-k slot — the common case — never pay the vocab sort; the key
    stays donated either way."""
    key, sub = jax.random.split(key)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    safe_t = jnp.where(temps > 0, temps, 1.0)
    lg = logits.astype(jnp.float32)
    vocab = lg.shape[-1]
    desc = jnp.sort(lg, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(
        desc, jnp.clip(top_ks - 1, 0, vocab - 1)[:, None], axis=-1)
    lg = jnp.where((top_ks[:, None] > 0) & (lg < kth), -jnp.inf, lg)
    keys = jax.random.split(sub, lg.shape[0])
    sampled = jax.vmap(jax.random.categorical)(keys,
                                               lg / safe_t[:, None])
    return jnp.where(temps > 0, sampled.astype(jnp.int32), greedy), key


# Sentinel return of a streaming admission aborted by cancellation
# (distinct from None, which means cluster-wide OOM).
_CANCELLED = object()

# Sentinel return of a streaming admission aborted by a cooperative
# pause request (overload preemption): same exact rollback as a cancel,
# but the request survives and returns to the waiting queue.
_PAUSED = object()


class InstanceEngine:
    """One serving instance (model replica)."""

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_local_len: int = 256, pool_blocks: int = 1024,
                 block_size: int = 16, inst_id: int = 0,
                 capacity_factor: float = -1.0, prefill_chunk: int = 32,
                 gpool=None):
        self.params = params
        self.cfg = cfg
        self.inst_id = inst_id
        self.max_batch = max_batch
        self.max_local_len = max_local_len
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        # Global-pool mode (cluster-installed GlobalKVPool): this
        # engine's KV lives in rank ``inst_id``'s slice of ONE
        # cluster-wide [NR, L, NB, bs, K, hd] tensor and the rManager
        # aliases the shared per-rank allocator, so the in-process
        # engine and the shard_map step see one layout.
        self.gpool = gpool
        self.rmanager = RManager(
            inst_id, pool_blocks, block_size,
            pool=(gpool.ranks[inst_id] if gpool is not None else None))
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.waiting: List[Request] = []
        self.stats = CommStats()
        self._key = jax.random.PRNGKey(1234 + inst_id)
        if gpool is not None and gpool.mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            self._key = jax.device_put(
                self._key, NamedSharding(gpool.mesh, P()))
        self._finished_events: List[int] = []
        self._can_pool = cfg.family in ("dense", "moe")
        self._pool_k = self._pool_v = None
        if self._can_pool:
            assert max_local_len >= 2 * block_size, \
                "local quota must cover at least two blocks"
            if gpool is None:
                L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
                dt = jnp.dtype(cfg.dtype)
                # THE serving KV store: every local or hosted byte
                # lives here (global mode: in gpool.k/gpool.v instead).
                self._pool_k = jnp.zeros(
                    (L, pool_blocks, block_size, K, hd), dt)
                self._pool_v = jnp.zeros(
                    (L, pool_blocks, block_size, K, hd), dt)
            self.state: Optional[DecodeState] = None
        else:
            self.state = init_decode_state(cfg, max_batch, max_local_len)
        # Sequence-ordered GLOBAL block chain [(inst_id, block_id)] per
        # request — maintained for creditor-spanning (and moved)
        # requests so _cache_insert can adopt the striped frames.
        self.req_chain: Dict[int, List[Tuple[int, int]]] = {}
        # Owner-side placement metadata: req_id -> creditor inst ids
        # hosting prefix spans (the KV itself is in THEIR pools).
        self.remote_insts: Dict[int, List[int]] = {}
        # Cluster-installed peer lookup (inst_id -> InstanceEngine) so the
        # decode step can read creditor pools directly.
        self.peers: Dict[int, "InstanceEngine"] = {}
        # Cluster-installed callback: commit creditor blocks for an
        # overflowing prompt prefix BEFORE any prefill compute.
        # sink(req, n_tokens, start=0) -> PrefixSink handle | None
        # (cluster OOM); ``start`` is the global token the creditor
        # region begins at (after any cached prefix); the chunk loop
        # streams KV rows in through handle.write().
        self.prefix_sink: Optional[Callable] = None
        # Cluster-installed cross-request prefix cache (None = disabled).
        # Admission walks it for the longest cached prefix; _finish
        # inserts the request's chain back.
        self.prefix_cache = None

    # The pool handles are properties so the whole serving stack —
    # stager staging, zero-copy pointer checks, prefix-cache block
    # transport — reads/threads the SAME arrays in both modes: the
    # private per-instance tensors, or the one global tensor.
    @property
    def pool_k(self):
        """Key pool tensor (private, or the global pool's alias)."""
        return self._pool_k if self.gpool is None else self.gpool.k

    @pool_k.setter
    def pool_k(self, val):
        """Rebind the key pool (donated-buffer round trips)."""
        if self.gpool is None:
            self._pool_k = val
        else:
            self.gpool.k = val

    @property
    def pool_v(self):
        """Value pool tensor (private, or the global pool's alias)."""
        return self._pool_v if self.gpool is None else self.gpool.v

    @pool_v.setter
    def pool_v(self, val):
        """Rebind the value pool (donated-buffer round trips)."""
        if self.gpool is None:
            self._pool_v = val
        else:
            self.gpool.v = val

    # ----------------------------------------------------------------- #
    def submit(self, req: Request) -> None:
        """Enqueue ``req`` on this instance's waiting list."""
        req.state = RequestState.WAITING
        self.waiting.append(req)

    @property
    def running(self) -> List[Request]:
        """Requests currently occupying decode slots."""
        return [r for r in self.slots if r is not None]

    @property
    def batch_size(self) -> int:
        """Number of occupied decode slots."""
        return len(self.running)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    # ----------------------------------------------------------------- #
    def _admit_one(self) -> bool:
        if not self.waiting:
            return False
        # Cancelled while queued: retire without spending any compute.
        if self.waiting[0].cancelled:
            self._cancel_finalize(self.waiting.pop(0))
            return True
        slot = self._free_slot()
        if slot is None:
            return False
        req = self.waiting[0]
        tokens = self._admit_tokens(req)
        T = len(tokens)
        bs = self.block_size
        # Admit with one block of quota headroom so the first decode
        # appends never breach the local budget before a reactive move
        # can run. The spilled prefix is block-aligned so creditor spans
        # are always whole blocks.
        cap = self.max_local_len - bs
        n_over = 0 if T <= cap else -(-(T - cap) // bs) * bs
        n_local = T - n_over
        need_blocks = -(-n_local // bs)
        # A cached prefix needs no fresh frames, and unpinned cache
        # replicas are reclaimable on demand — count both as headroom
        # (the actual eviction happens lazily in _admit_streaming).
        evictable = (self.prefix_cache.evictable(self.inst_id)
                     if self.prefix_cache is not None else 0)
        if self.rmanager.pool.alloc.free_count + evictable < need_blocks:
            return False
        if n_over and (not self._can_pool or self.prefix_sink is None):
            req.state = RequestState.FAILED      # cannot span: no KV pool
            req.finish_time = time.monotonic()
            self.waiting.pop(0)
            self._finished_events.append(req.req_id)
            return True
        self.waiting.pop(0)

        if self._can_pool:
            logits = self._admit_streaming(req, tokens, n_over, n_local)
            if logits is None:                   # cluster-wide OOM
                req.state = RequestState.FAILED
                req.finish_time = time.monotonic()
                self._finished_events.append(req.req_id)
                return True
            if logits is _CANCELLED:             # aborted mid-prefill
                self._cancel_finalize(req)
                return True
            if logits is _PAUSED:
                # Paused mid-prefill: the admission rolled back exactly;
                # the request returns to the head of the queue and is
                # re-admitted (re-prefilled) on a later step. Returning
                # False ends this step's admission sweep — the freed
                # capacity is the point of the pause.
                req.pause_requested = False
                req.preemptions += 1
                req.paused_at = time.monotonic()
                req.state = RequestState.WAITING
                self.waiting.insert(0, req)
                return False
        else:
            logits = self._admit_dense(req, slot, tokens, n_local)
        self.rmanager.set_owner(req.req_id, True)
        req.slot = slot
        req.state = RequestState.RUNNING
        self.slots[slot] = req
        if req.needs_replay and req.output:
            # Replay re-admission (crash recovery): the KV now covers
            # prompt + output[:-1] — exactly the state an unfailed
            # decode would hold. The final prefill logits would merely
            # re-produce output[-1] (already emitted to the stream), so
            # NOTHING is emitted here; the next decode step feeds
            # output[-1], the normal decode input convention.
            req.needs_replay = False
            req.replays += 1
            req.replayed_tokens += len(req.output) - 1
            return True
        req.needs_replay = False
        # First generated token comes from the final prefill logits.
        self._emit(req, int(self._sample_tokens(logits, [req])[0]))
        return True

    def _admit_tokens(self, req: Request) -> List[int]:
        """The token sequence admission must prefill: the prompt, or —
        for a crash-recovery replay — prompt + output[:-1] (every
        generated token except the last, whose KV row was never
        written: the next decode step feeds it, exactly as it would
        have on an unfailed instance)."""
        if req.needs_replay and req.output:
            return list(req.prompt) + list(req.output[:-1])
        return list(req.prompt)

    def _admit_dense(self, req: Request, slot: int, tokens: List[int],
                     n_local: int) -> jax.Array:
        """Hybrid/ssm admission: dense prefill into a DecodeState slot."""
        T = len(tokens)
        tok_arr = jnp.asarray([tokens], jnp.int32)
        logits, full_state = prefill(self.params, self.cfg, tok_arr,
                                     max_len=T)
        if full_state.kv_k is not None:
            self.stats.admit_stage_bytes = max(
                self.stats.admit_stage_bytes,
                int(2 * full_state.kv_k.size
                    * full_state.kv_k.dtype.itemsize))
        req_state = repack_ring(full_state, self.max_local_len,
                                n_keep=min(n_local, self.max_local_len))
        self.state = write_slot(self.state, slot, req_state, self.cfg)
        self.rmanager.pool.append_tokens(req.req_id, n_local)
        return logits

    def _ensure_free(self, n_blocks: int) -> bool:
        """Make ``n_blocks`` frames allocatable, evicting unpinned
        prefix-cache replicas on demand (they spill to the host tier
        when one is configured)."""
        alloc = self.rmanager.pool.alloc
        if alloc.free_count >= n_blocks:
            return True
        if self.prefix_cache is not None:
            self.prefix_cache.evict_device(
                self.inst_id, n_blocks - alloc.free_count)
        return alloc.free_count >= n_blocks

    def _copy_block_rows(self, src_blk: int, dst_blk: int,
                         n_rows: int) -> None:
        """Copy the first ``n_rows`` token rows of one pool block into
        another (the copy-on-write tail split). Dispatch only — the
        functional dependencies order it against later pool updates."""
        blk = np.full(n_rows, dst_blk, np.int32)
        off = np.arange(n_rows, dtype=np.int32)
        if self.gpool is not None:
            k, v = self.gpool.read_blocks(self.inst_id, [src_blk])
            self.gpool.scatter_rows(self.inst_id, blk, off,
                                    k[:, :n_rows], v[:, :n_rows])
            return
        k = read_pool_rows(self.pool_k, [src_blk],
                           self.block_size)[:, :n_rows]
        v = read_pool_rows(self.pool_v, [src_blk],
                           self.block_size)[:, :n_rows]
        self.pool_k = scatter_pool_rows(self.pool_k, blk, off, k)
        self.pool_v = scatter_pool_rows(self.pool_v, blk, off, v)

    def _admit_cached_prefix(self, req: Request, tokens: List[int],
                             n_local: int) -> Tuple[int, int]:
        """Walk the prefix cache and attach the longest cached prefix to
        the request's local chain. Returns ``(n_cached, write_from)``:
        the global token count admission may skip prefilling, and the
        first global token index the stream may WRITE pool rows for.

        Shared full blocks are attached by reference (one allocator ref
        each). A FULL-prompt hit takes the copy-on-write path: the first
        m-1 blocks are shared and the last is COPIED WHOLE into a
        private frame, so decode appends land in request-private frames
        — a shared frame is never mutated. The final prompt token is
        still re-run through one prefill chunk (its logits sample the
        first output token) but with its pool write SUPPRESSED
        (``write_from = T``): its cached KV row — written at the
        original chunk alignment — stays byte-identical, so a warm
        request's decode attends over exactly the bytes a cold run
        would have produced."""
        cache, pool, bs = self.prefix_cache, self.rmanager.pool, \
            self.block_size
        rid, T = req.req_id, len(tokens)
        shared = cache.acquire(self.inst_id, rid, tokens,
                               max_blocks=n_local // bs)
        if not shared:
            return 0, 0
        m = len(shared)
        if m * bs == T:
            pool.attach_shared(rid, shared[:m - 1], bs)
            n_cached = T - 1
            cow_src = shared[m - 1]
        else:
            pool.attach_shared(rid, shared, bs)
            n_cached = m * bs
            cow_src = None
        tail_blocks = -(-(n_local - (len(shared) - (1 if cow_src
                                                    is not None else 0))
                          * bs) // bs)
        if not self._ensure_free(tail_blocks) or \
                not pool.append_tokens(rid, n_local - pool.tokens_of(rid)):
            pool.release(rid)
            cache.release(rid)
            return 0, 0
        if cow_src is not None:
            cow = pool.requests[rid].blocks[-1]
            self._copy_block_rows(cow_src, cow, bs)
            cache.stats.cow_copies += 1
        self.stats.cache_hit_tokens += n_cached
        return n_cached, (T if cow_src is not None else 0)

    def _admit_streaming(self, req: Request, tokens: List[int],
                         n_over: int, n_local: int):
        """Dense/moe admission: reserve every block, then stream chunks.

        All placement decisions happen BEFORE any compute: the longest
        cached prefix is pinned from the prefix cache (when enabled),
        creditor blocks for the overflow prefix are committed via the
        reserve-then-stream ``prefix_sink`` and the local tail's blocks
        are allocated here, so a failed admission costs zero FLOPs.
        Returns the final chunk's logits, None on cluster-wide OOM, or
        the ``_CANCELLED`` sentinel when the request was cancelled
        mid-stream — in that case every reservation (local blocks,
        committed creditor spans AND cache pins) is rolled back,
        allocator state restored exactly.
        """
        rid = req.req_id
        req.state = RequestState.PREFILLING
        cache = self.prefix_cache
        n_cached, write_from = 0, 0
        if cache is not None:
            n_cached, write_from = self._admit_cached_prefix(
                req, tokens, n_local)
        sink = None
        if n_over:
            sink = self.prefix_sink(req, n_over, start=n_cached)
            if sink is None:
                self.rmanager.release_request(rid)
                if cache is not None:
                    cache.release(rid)
                return None
        if not n_cached:
            # Cold path: the cached branch already appended its tail.
            if not self._ensure_free(-(-n_local // self.block_size)) or \
                    not self.rmanager.pool.append_tokens(rid, n_local):
                if sink is not None:
                    sink.abort()
                self.rmanager.release_request(rid)
                if cache is not None:
                    cache.release(rid)
                return None
        logits = self._stream_prefill(req, tokens, n_over, n_local, sink,
                                      n_cached=n_cached,
                                      write_from=write_from)
        if logits is _CANCELLED or logits is _PAUSED:
            # Abort the in-flight admission: drain staged creditor
            # writes, drop the committed spans (metadata release — the
            # all-or-nothing machinery's rollback), free local blocks.
            # Cache pins are released in _release_slot, exactly once —
            # except on a PAUSE, which never reaches a terminal path,
            # so its pins are released here.
            if sink is not None:
                sink.abort()
            self.rmanager.release_request(rid)
            if logits is _PAUSED and cache is not None:
                cache.release(rid)
            return logits
        if sink is not None:
            self.remote_insts[rid] = list(sink.rank_ids)
            L, K, hd = (self.cfg.num_layers, self.cfg.num_kv_heads,
                        self.cfg.head_dim)
            itemsize = jnp.dtype(self.cfg.dtype).itemsize
            self.stats.kv_moved += int(2 * L * n_over * K * hd) * itemsize
            # Record the GLOBAL chain — cached + striped creditor +
            # local tail blocks in token order (with a sink, n_cached is
            # always block-aligned: a full-prompt COW hit implies
            # n_over == 0). _cache_insert adopts it on finish.
            local = self.rmanager.pool.requests[rid].blocks
            m = n_cached // self.block_size
            chain = [(self.inst_id, b) for b in local[:m]]
            for inst, _start, blks in sink.spans:
                chain += [(inst, b) for b in blks]
            chain += [(self.inst_id, b) for b in local[m:]]
            self.req_chain[rid] = chain
        return logits

    def _stream_prefill(self, req: Request, tokens: List[int],
                        n_over: int, n_local: int, sink,
                        n_cached: int = 0,
                        write_from: int = 0) -> jax.Array:
        """Drive ``prefill_chunk_paged`` over the prompt, O(chunk) peak.

        Per chunk: local rows scatter into the pool inside the jitted
        step; creditor-bound rows come back as the chunk KV export and
        stream out through ``sink.write`` — the only transient arrays
        are chunk-sized, never [T]-sized.

        With a cached prefix the stream starts at ``n_cached``: global
        tokens [0, n_cached) are already resident in the local chain's
        leading (shared) blocks, the creditor region shifts to
        [n_cached, n_cached + n_over), and the local tail holds
        [n_cached + n_over, T) — chain index of global token t stays
        ``t - n_over`` because the chain is cached blocks then tail in
        global token order. Cross-region contiguity is not required:
        pool rows carry position-encoded KV, so attention over the
        union of the covered tables is exact.
        """
        if self.gpool is not None:
            return self._stream_prefill_global(req, tokens, n_over,
                                               n_local, sink,
                                               n_cached, write_from)
        rid = req.req_id
        T = len(tokens)
        bs, C = self.block_size, self.prefill_chunk
        pool = self.rmanager.pool
        NB = pool.alloc.num_blocks
        local_blocks = pool.requests[rid].blocks
        cred_ids = list(sink.rank_ids) if sink is not None else []
        rank_pools = [pool] + [self.peers[d].rmanager.pool
                               for d in cred_ids]
        cred_end = n_cached + n_over     # first locally-written token
        logits = None
        for t0 in range(n_cached, T, C):
            if req.cancelled or req.pause_requested:
                # Cooperative abort point: between chunks, before any
                # more compute or creditor writes are dispatched. A
                # pause rolls back identically but keeps the request.
                return _CANCELLED if req.cancelled else _PAUSED
            t1 = min(t0 + C, T)
            n_valid = t1 - t0
            toks = np.zeros(C, np.int32)
            toks[:n_valid] = tokens[t0:t1]
            # Owner-pool write target per chunk row; creditor-bound and
            # padded rows carry block id NB (out of range => dropped).
            wblk = np.full(C, NB, np.int32)
            woff = np.zeros(C, np.int32)
            # ``write_from`` suppresses pool writes for re-run tokens
            # whose KV is already resident (the COW full-hit's final
            # prompt token: computed for logits only, never re-written).
            lo = max(t0, cred_end, write_from)
            if lo < t1:
                blk, off = rows_for_token_range(local_blocks, bs,
                                                lo - n_over, t1 - n_over)
                wblk[lo - t0:t1 - t0] = blk
                woff[lo - t0:t1 - t0] = off
            # Tables address exactly the already-resident tokens [0, t0):
            # the cached prefix plus whatever this stream has written.
            covered = [min(n_cached + max(t0 - cred_end, 0), n_local)]
            if sink is not None:
                cov = sink.coverage(min(t0, cred_end))
                covered += [cov[d] for d in cred_ids]
            needed = max(1, max(-(-c // bs) for c in covered))
            tables, tails = prefix_tables(rank_pools, rid, covered,
                                          table_bucket(needed))
            # Re-read creditor pools every chunk: sink writes rebind
            # the peers' pool tensors between steps.
            remote = tuple((self.peers[d].pool_k, self.peers[d].pool_v)
                           for d in cred_ids)
            logits, self.pool_k, self.pool_v, k_c, v_c = \
                prefill_chunk_paged(
                    self.params, self.cfg, toks, t0, n_valid,
                    self.pool_k, self.pool_v, tables, tails, wblk, woff,
                    remote_pools=remote)
            if sink is not None and t0 < cred_end:
                hi = min(t1, cred_end)
                sink.write(t0, k_c[:, :hi - t0], v_c[:, :hi - t0])
            self.stats.admit_stage_bytes = max(
                self.stats.admit_stage_bytes,
                int((k_c.size + v_c.size) * k_c.dtype.itemsize))
        if sink is not None:
            # Table-commit point: the creditor spans become part of this
            # request's decode view now, so the staged (possibly still
            # in-flight) row writes are drained here — and only here.
            sink.flush()
        return logits

    def _stream_prefill_global(self, req: Request, tokens: List[int],
                               n_over: int, n_local: int, sink,
                               n_cached: int = 0,
                               write_from: int = 0):
        """``_stream_prefill`` over the GLOBAL pool tensor.

        One ``prefill_chunk_global`` per chunk: the prefix partial runs
        over EVERY rank's slice (vmap, or shard_map + collective merge
        under a mesh) and creditor-striped rows are written by the SAME
        deferred in-step scatter as owner rows — ``sink.write``'s
        host_kv_rows round-trip disappears; the sink survives only as
        the reservation/coverage ledger (its flush is a no-op drain).
        """
        rid = req.req_id
        T = len(tokens)
        bs, C = self.block_size, self.prefill_chunk
        gpool = self.gpool
        pool = self.rmanager.pool
        NB = pool.alloc.num_blocks
        local_blocks = pool.requests[rid].blocks
        cred_ids = list(sink.rank_ids) if sink is not None else []
        cred_end = n_cached + n_over     # first locally-written token
        logits = None
        for t0 in range(n_cached, T, C):
            if req.cancelled or req.pause_requested:
                return _CANCELLED if req.cancelled else _PAUSED
            t1 = min(t0 + C, T)
            n_valid = t1 - t0
            toks = np.zeros(C, np.int32)
            toks[:n_valid] = tokens[t0:t1]
            # Per-row (rank, block, offset) target; padded rows and
            # suppressed rewrites keep the out-of-range block sentinel.
            wrank = np.full(C, self.inst_id, np.int32)
            wblk = np.full(C, NB, np.int32)
            woff = np.zeros(C, np.int32)
            if sink is not None and t0 < cred_end:
                hi = min(t1, cred_end)
                rr, bb, oo = sink.row_targets(t0, hi)
                wrank[:hi - t0] = rr
                wblk[:hi - t0] = bb
                woff[:hi - t0] = oo
            lo = max(t0, cred_end, write_from)
            if lo < t1:
                blk, off = rows_for_token_range(local_blocks, bs,
                                                lo - n_over, t1 - n_over)
                wblk[lo - t0:t1 - t0] = blk
                woff[lo - t0:t1 - t0] = off
            # Coverage over ALL global ranks: the owner's cached+written
            # prefix, each creditor's streamed span, zero elsewhere.
            covered = [0] * gpool.n_ranks
            covered[self.inst_id] = min(
                n_cached + max(t0 - cred_end, 0), n_local)
            if sink is not None:
                cov = sink.coverage(min(t0, cred_end))
                for d in cred_ids:
                    covered[d] = cov[d]
            needed = max(1, max(-(-c // bs) for c in covered))
            tables, tails = prefix_tables(gpool.ranks, rid, covered,
                                          table_bucket(needed))
            logits, gpool.k, gpool.v, k_c, v_c = prefill_chunk_global(
                self.params, self.cfg, toks, t0, n_valid,
                gpool.k, gpool.v, tables[:, 0], tails[:, 0],
                wrank, wblk, woff, mesh=gpool.mesh,
                pool_axes=gpool.pool_axes)
            self.stats.admit_stage_bytes = max(
                self.stats.admit_stage_bytes,
                int((k_c.size + v_c.size) * k_c.dtype.itemsize))
        if sink is not None:
            sink.flush()
        return logits

    def _sample_tokens(self, logits, reqs) -> np.ndarray:
        """Sampled tokens for a batch of slots: ONE device call + ONE
        host readback (not one per slot per step)."""
        temps = jnp.asarray(
            [(r.sampling.temperature if r is not None else 0.0)
             for r in reqs], jnp.float32)
        ks = [(r.sampling.top_k if r is not None else 0) for r in reqs]
        if any(ks):
            toks, self._key = _sample_batch_topk(
                self._key, logits, temps, jnp.asarray(ks, jnp.int32))
        else:
            toks, self._key = _sample_batch(self._key, logits, temps)
        return np.asarray(toks)

    def _emit(self, req: Request, tok: int) -> None:
        req.output.append(tok)
        req.token_times.append(time.monotonic())
        s = req.sampling
        if (len(req.output) >= s.max_new_tokens
                or (s.eos_token is not None and tok == s.eos_token)
                or tok in s.stop_tokens):
            self._finish(req)

    def _finish(self, req: Request) -> None:
        req.state = RequestState.FINISHED
        req.finish_time = time.monotonic()
        self._cache_insert(req)
        self._release_slot(req)

    def _cache_insert(self, req: Request) -> None:
        """Adopt a finished request's full blocks into the prefix cache
        BEFORE the chain is released — the cache's incref keeps each
        adopted frame alive through the release's decref, so a finished
        request's prefix spills/caches instead of dropping.
        Creditor-SPANNING requests insert their GLOBAL chain
        (``req_chain``: striped creditor frames + local tail, in token
        order) via ``insert_chain_multi`` — each frame is adopted in
        its own instance's allocator, so the striped prefix warm-hits
        follow-up requests instead of dropping with the span."""
        cache = self.prefix_cache
        if cache is None or not self._can_pool or req.cancelled:
            return
        rb = self.rmanager.pool.requests.get(req.req_id)
        if rb is None or not rb.blocks:
            return
        # KV exists for the prompt plus every DECODED INPUT token — the
        # last sampled token was never fed back, so its KV was never
        # written.
        tokens = list(req.prompt) + list(req.output[:-1])
        if self.remote_insts.get(req.req_id):
            chain = self.req_chain.get(req.req_id)
            if chain is None:
                return
            total = (len(chain) - 1) * self.block_size + rb.tail_tokens
            cache.insert_chain_multi(chain, tokens[:total])
            return
        tokens = tokens[:rb.n_tokens(self.block_size)]
        cache.insert_chain(self.inst_id, tokens, rb.blocks)

    def _fail(self, req: Request) -> None:
        req.state = RequestState.FAILED
        req.finish_time = time.monotonic()
        self._release_slot(req)

    def _cancel_finalize(self, req: Request) -> None:
        """Terminal bookkeeping shared by every cancellation path."""
        req.state = RequestState.CANCELLED
        req.finish_time = time.monotonic()
        self._release_slot(req)

    def cancel(self, req: Request) -> bool:
        """Cancel a request this engine holds (waiting or running).

        Returns True when the request was retired HERE (slot released,
        local blocks freed, finished event queued). A request that is
        mid-streaming-prefill only gets its flag set — the chunk loop
        aborts and rolls back at its next cooperative check. Creditor-
        hosted spans are the cluster's to release (it sees the finished
        event, exactly once, like any other terminal state).
        """
        if req.done:
            return False
        req.cancelled = True
        if req in self.waiting:
            self.waiting.remove(req)
            self._cancel_finalize(req)
            return True
        if req.slot is not None and self.slots[req.slot] is req:
            self._cancel_finalize(req)
            return True
        return False

    def _release_slot(self, req: Request) -> None:
        if req.slot is not None:
            self.slots[req.slot] = None
            req.slot = None
        self.rmanager.release_request(req.req_id)
        if self.prefix_cache is not None:
            # Unpin the request's cached-prefix nodes — exactly once
            # (the pin list is popped), on every terminal path.
            self.prefix_cache.release(req.req_id)
        self.remote_insts.pop(req.req_id, None)
        self.req_chain.pop(req.req_id, None)
        self._finished_events.append(req.req_id)

    def drain_finished(self) -> List[int]:
        """Req ids finished/failed since the last drain, each reported
        once — the cluster releases their creditor-hosted spans from
        this instead of rescanning every request ever submitted."""
        out, self._finished_events = self._finished_events, []
        return out

    # ----------------------------------------------------------------- #
    def _chain_append(self, req: Request) -> None:
        """Keep the request's GLOBAL chain in step with the local one:
        a decode append that opened a fresh tail block extends it."""
        chain = self.req_chain.get(req.req_id)
        if chain is None:
            return
        rb = self.rmanager.pool.requests[req.req_id]
        if rb.tail_tokens == 1:
            chain.append((self.inst_id, rb.blocks[-1]))

    def _append_step_tokens(self) -> None:
        """Reserve this step's token in each request's tail block. A
        failed append means the pool is exhausted: reject loudly,
        never corrupt (paper: reject when pool exhausted)."""
        pool = self.rmanager.pool
        for r in list(self.slots):
            if r is None:
                continue
            if not pool.append_tokens(r.req_id, 1):
                # Unpinned prefix-cache replicas are reclaimable: evict
                # one and retry before rejecting the request.
                if self._ensure_free(1) and pool.append_tokens(r.req_id,
                                                               1):
                    self._chain_append(r)
                    continue
                self._fail(r)
            else:
                self._chain_append(r)

    def _step_paged(self) -> Optional[jnp.ndarray]:
        """One decode iteration over the pool path. Returns logits."""
        if self.gpool is not None:
            return self._step_paged_global()
        pool = self.rmanager.pool
        t0 = time.perf_counter()
        self._append_step_tokens()
        running = self.running
        if not running:
            return None
        B, NB = self.max_batch, pool.alloc.num_blocks
        tokens = np.zeros(B, np.int32)
        lens = np.zeros(B, np.int32)
        wblk = np.full(B, NB, np.int32)      # NB = out of range => dropped
        woff = np.zeros(B, np.int32)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            tokens[i] = r.output[-1] if r.output else r.prompt[-1]
            lens[i] = r.length - 1           # abs position of the new token
            rb = pool.requests[r.req_id]
            wblk[i] = rb.blocks[-1]
            woff[i] = rb.tail_tokens - 1
        insts = sorted({i for r in running
                        for i in self.remote_insts.get(r.req_id, ())})
        rank_pools = [pool] + [self.peers[i].rmanager.pool for i in insts]
        req_ids = [r.req_id if r is not None else -1 for r in self.slots]
        needed = max((len(p.requests[rid].blocks)
                      for p in rank_pools for rid in req_ids
                      if rid in p.requests), default=1)
        tables, tails = build_local_tables(rank_pools, req_ids,
                                           table_bucket(needed))
        remote_pools = tuple((self.peers[i].pool_k, self.peers[i].pool_v)
                             for i in insts)
        self.stats.host_gather_s += time.perf_counter() - t0
        self.stats.decode_steps += 1

        # The pools are DONATED into the step: the returned arrays are
        # the same device buffers updated in place (stale-handle
        # discipline — self.pool_k/v are the only live references).
        ptr = buffer_ptr(self.pool_k)
        logits, self.pool_k, self.pool_v = decode_step_paged(
            self.params, self.cfg, tokens, lens, self.pool_k, self.pool_v,
            tables, tails, wblk, woff, remote_pools=remote_pools)
        if ptr is not None and buffer_ptr(self.pool_k) != ptr:
            self.stats.pool_copy_steps += 1

        # Account the paper's per-step merge traffic — q + (o, m, l) —
        # once per (request, creditor) span entry, matching the per-rank
        # partial exchanges a real deployment would make.
        H, hd = self.cfg.num_heads, self.cfg.head_dim
        L = self.cfg.num_layers
        entries = sum(len(self.remote_insts.get(r.req_id, ()))
                      for r in running)
        self.stats.query_shipped += int(
            entries * L * (H * hd * 2 + H * hd * 4 + 2 * H * 4))
        return logits

    def _step_paged_global(self) -> Optional[jnp.ndarray]:
        """One decode iteration over the GLOBAL pool tensor.

        One ``decode_step_global`` call covers the owner AND every
        creditor rank: tables come from the shared per-rank allocators
        (``gpool.ranks``), the step LSE-merges per-rank partials (vmap,
        or shard_map + pmax/psum under a mesh), and the new token's KV
        lands via the deferred tail scatter — the pending slot is
        excluded from the tables (it enters as the self partial)."""
        gpool = self.gpool
        pool = self.rmanager.pool
        t0 = time.perf_counter()
        self._append_step_tokens()
        running = self.running
        if not running:
            return None
        B, NB = self.max_batch, pool.alloc.num_blocks
        tokens = np.zeros(B, np.int32)
        lens = np.zeros(B, np.int32)
        wblk = np.full(B, NB, np.int32)      # NB = out of range => dropped
        woff = np.zeros(B, np.int32)
        req_ids = [r.req_id if r is not None else -1 for r in self.slots]
        needed = max((len(p.requests[rid].blocks)
                      for p in gpool.ranks for rid in req_ids
                      if rid in p.requests), default=1)
        tables, tails = build_local_tables(gpool.ranks, req_ids,
                                           table_bucket(needed))
        own = self.inst_id
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            tokens[i] = r.output[-1] if r.output else r.prompt[-1]
            lens[i] = r.length - 1       # abs position of the new token
            rb = pool.requests[r.req_id]
            wblk[i] = rb.blocks[-1]
            woff[i] = rb.tail_tokens - 1
            # Deferred-write schedule: the pending token's slot must not
            # be visible to the pooled partial (its row is garbage until
            # the post-scan scatter) — it joins as the self partial.
            if rb.tail_tokens == 1:
                tables[own, i, len(rb.blocks) - 1] = -1
                tails[own, i] = self.block_size
            else:
                tails[own, i] = rb.tail_tokens - 1
        self.stats.host_gather_s += time.perf_counter() - t0
        self.stats.decode_steps += 1

        ptr = buffer_ptr(gpool.k)
        logits, gpool.k, gpool.v = decode_step_global(
            self.params, self.cfg, tokens, lens, gpool.k, gpool.v,
            tables, tails, wblk, woff, rank=own, mesh=gpool.mesh,
            pool_axes=gpool.pool_axes)
        if ptr is not None and buffer_ptr(gpool.k) != ptr:
            self.stats.pool_copy_steps += 1

        H, hd = self.cfg.num_heads, self.cfg.head_dim
        L = self.cfg.num_layers
        entries = sum(len(self.remote_insts.get(r.req_id, ()))
                      for r in running)
        self.stats.query_shipped += int(
            entries * L * (H * hd * 2 + H * hd * 4 + 2 * H * 4))
        return logits

    def step(self) -> int:
        """Admit + one decode iteration. Returns #tokens generated."""
        # Retire slots whose cancel flag was set since the last step
        # (e.g. from a streaming consumer) before any decode compute.
        for r in list(self.slots):
            if r is not None and r.cancelled and not r.done:
                self._cancel_finalize(r)
        while self._admit_one():
            pass
        if not self.running:
            self.rmanager.batch_size = 0
            return 0

        if self._can_pool:
            logits = self._step_paged()
            if logits is None:
                self.rmanager.batch_size = 0
                return 0
        else:
            tokens = np.zeros(self.max_batch, np.int32)
            for i, r in enumerate(self.slots):
                if r is not None:
                    tokens[i] = r.output[-1] if r.output else r.prompt[-1]
            logits, self.state = decode_step(self.params, self.cfg,
                                             self.state,
                                             jnp.asarray(tokens))
            for r in self.running:
                self.rmanager.pool.append_tokens(r.req_id, 1)

        made = 0
        reqs = list(self.slots)
        toks = self._sample_tokens(logits, reqs)
        for r, tok in zip(reqs, toks):
            if r is None:
                continue
            self._emit(r, int(tok))
            made += 1
        self.rmanager.batch_size = self.batch_size
        return made

    # --- KV movement (debtor side) ------------------------------------ #
    def local_tokens(self, req: Request) -> int:
        """Tokens of ``req`` resident in THIS instance's pool."""
        return self.rmanager.pool.tokens_of(req.req_id)

    def local_free_tokens(self, req: Request) -> int:
        """Quota slots left AFTER the pending token's append."""
        return self.max_local_len - self.local_tokens(req) - 1

    def extract_prefix_kv(self, req: Request, n_blocks: int):
        """Read the OLDEST n full blocks' rows of this rank's span of
        ``req`` out of the pool — the request's local prefix when this
        rank owns it, or the hosted span when this rank is a creditor
        being reclaimed (striped-plan eviction path)."""
        blocks = self.rmanager.pool.requests[req.req_id].blocks[:n_blocks]
        if self.gpool is not None:
            k, v = self.gpool.read_blocks(self.inst_id, blocks)
            return k[:, None], v[:, None]
        k = read_pool_rows(self.pool_k, blocks, self.block_size)
        v = read_pool_rows(self.pool_v, blocks, self.block_size)
        return k[:, None], v[:, None]        # [L, 1, n*bs, K, hd]

    # --- prefix-cache block transport ----------------------------------#
    def read_block_rows(self, block: int):
        """One pool block's rows as independent [L, bs, K, hd] arrays
        (a gather — safe to keep after the frame is freed and reused;
        the functional dependencies order it before any overwrite)."""
        if self.gpool is not None:
            return self.gpool.read_blocks(self.inst_id, [block])
        k = read_pool_rows(self.pool_k, [block], self.block_size)
        v = read_pool_rows(self.pool_v, [block], self.block_size)
        return k, v

    def write_block_rows(self, block: int, k, v) -> None:
        """Fill one pool block from [L, bs, K, hd] rows (host or device
        arrays — an H2D prefetch upload or a D2D peer replica copy)."""
        if self.gpool is not None:
            self.gpool.write_blocks(self.inst_id, [block], jnp.asarray(k),
                                    jnp.asarray(v))
            return
        self.pool_k = write_pool_rows(self.pool_k, [block],
                                      jnp.asarray(k), self.block_size)
        self.pool_v = write_pool_rows(self.pool_v, [block],
                                      jnp.asarray(v), self.block_size)

    # --- creditor side -------------------------------------------------#
    def host_kv(self, req_id: int, blocks: List[int], k, v) -> None:
        """Write an arriving span's rows into already-committed blocks.

        k/v: [L, 1, n, K, hd] with n == len(blocks) * block_size (spans
        are always whole blocks).
        """
        if self.gpool is not None:
            self.gpool.write_blocks(self.inst_id, blocks, k[:, 0], v[:, 0])
            return
        self.pool_k = write_pool_rows(self.pool_k, blocks, k[:, 0],
                                      self.block_size)
        self.pool_v = write_pool_rows(self.pool_v, blocks, v[:, 0],
                                      self.block_size)

    def host_kv_rows(self, req_id: int, block_ids, offsets, k, v) -> None:
        """Scatter a streaming-prefill span's rows into already-committed
        blocks, row-addressed (may land mid-block).

        k/v: [L, n, K, hd] with row i bound for
        ``(block_ids[i], offsets[i])`` of this pool.
        """
        if self.gpool is not None:
            self.gpool.scatter_rows(self.inst_id, block_ids, offsets, k, v)
            return
        self.pool_k = scatter_pool_rows(self.pool_k, block_ids, offsets, k)
        self.pool_v = scatter_pool_rows(self.pool_v, block_ids, offsets, v)

    def drop_hosted(self, req_id: int) -> None:
        """Release a hosted span — pure metadata; rows are reused later."""
        self.rmanager.release_request(req_id)

    # --- preemption (overload survival) -------------------------------- #
    def chain_of(self, req: Request) -> List[Tuple[int, int]]:
        """The request's GLOBAL block chain in token order: the striped
        ``req_chain`` when it spans creditors (or was moved), else its
        purely local block list."""
        chain = self.req_chain.get(req.req_id)
        if chain is not None:
            return chain
        rb = self.rmanager.pool.requests.get(req.req_id)
        return [(self.inst_id, b) for b in rb.blocks] if rb else []

    def read_chain_frames(self, req: Request):
        """Gather every block of a request's KV chain (cross-engine for
        creditor spans) as independent ``(k, v)`` frame pairs of shape
        [L, bs, K, hd], in token order.

        Returns ``(n_resident_tokens, frames)`` or None when the chain
        is unreadable (unknown request, dead creditor). The gathers do
        not alias the pools, so the caller may release the blocks right
        after — JAX's functional dependencies order the reads before
        any later reuse of the frames."""
        rid = req.req_id
        rb = self.rmanager.pool.requests.get(rid)
        if rb is None or not rb.blocks:
            return None
        chain = self.chain_of(req)
        if not chain:
            return None
        frames = []
        for inst, blk in chain:
            eng = self if inst == self.inst_id else self.peers.get(inst)
            if eng is None:
                return None
            frames.append(eng.read_block_rows(blk))
        n_tokens = (len(chain) - 1) * self.block_size + rb.tail_tokens
        return n_tokens, frames

    def finalize_pause(self, req: Request,
                       now: Optional[float] = None) -> None:
        """Release a RUNNING request's device state and park it PAUSED.

        Called by the preemptor AFTER its KV chain has been read and
        stored host-side: the slot, local blocks (decref'ing shared
        cache frames) and cache pins are released through the same
        ``_release_slot`` discipline as every terminal path — the
        finished event it queues lets the cluster drop any creditor
        span not already dropped, exactly once. The request itself
        keeps its prompt/output/stream state and is NOT terminal."""
        req.state = RequestState.PAUSED
        req.preemptions += 1
        req.paused_at = time.monotonic() if now is None else now
        self._release_slot(req)

    def resume_paused(self, req: Request, n_tokens: int,
                      frames, remote_layout=None) -> bool:
        """Re-admit a PAUSED request by restoring its KV chain, without
        recompute.

        Reserves a fresh placement — a local tail (plus one block of
        decode headroom) and, when ``n_tokens`` overflows the local
        quota, block-aligned creditor spans committed through the
        reserve-then-stream prefix sink. When ``remote_layout`` (the
        paused chain's creditor runs as ``[(inst_id, n_blocks)]``) is
        given, the SAME local/remote partition — and preferentially the
        same creditors — is reproduced instead of recomputing the split
        from admission's quota math: the partition decides the
        LSE-merge grouping, so reproducing it keeps the resumed greedy
        stream bit-identical to the unpreempted run rather than merely
        byte-identical in KV. The saved ``frames`` (chain order) are
        uploaded H2D into the reserved blocks: creditor spans first
        (tokens [0, n_over)), local tail after. Rollback is exact on
        any reservation failure (sink abort + block release), leaving
        the request PAUSED and resumable elsewhere. On success the
        request is RUNNING in a slot and the next decode step feeds
        ``output[-1]`` over byte-identical KV."""
        if not self._can_pool:
            return False
        slot = self._free_slot()
        if slot is None:
            return False
        rid, bs = req.req_id, self.block_size
        if remote_layout:
            n_over = sum(nb for _, nb in remote_layout) * bs
        else:
            cap = self.max_local_len - bs
            n_over = 0 if n_tokens <= cap \
                else -(-(n_tokens - cap) // bs) * bs
        n_local = n_tokens - n_over
        if n_over and self.prefix_sink is None:
            return False
        sink = None
        if n_over:
            sink = self.prefix_sink(req, n_over, start=0,
                                    prefer=remote_layout)
            if sink is None:
                return False
        if not self._ensure_free(-(-n_local // bs)) or \
                not self.rmanager.pool.append_tokens(rid, n_local):
            if sink is not None:
                sink.abort()
            self.rmanager.release_request(rid)
            return False
        idx = 0
        if sink is not None:
            for inst, _start, blks in sink.spans:
                eng = self.peers[inst]
                for b in blks:
                    k, v = frames[idx]
                    idx += 1
                    eng.write_block_rows(b, k, v)
            sink.flush()
            self.remote_insts[rid] = list(sink.rank_ids)
        local = self.rmanager.pool.requests[rid].blocks
        for b in local:
            k, v = frames[idx]
            idx += 1
            self.write_block_rows(b, k, v)
            self.stats.host_prefetch_bytes += int(
                k.size * k.dtype.itemsize + v.size * v.dtype.itemsize)
        assert idx == len(frames), "chain frames != reserved blocks"
        if sink is not None:
            chain = [(inst, b) for inst, _start, blks in sink.spans
                     for b in blks]
            chain += [(self.inst_id, b) for b in local]
            self.req_chain[rid] = chain
        self.rmanager.set_owner(rid, True)
        req.slot = slot
        req.state = RequestState.RUNNING
        self.slots[slot] = req
        return True
