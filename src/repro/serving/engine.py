"""Single-instance serving engine: continuous batching over fixed slots.

ORCA-style iteration-level scheduling: each ``step()`` admits waiting
requests into free slots (prefill), then runs ONE decode iteration for
all running slots. The local KV lives in a ring cache of ``max_local_len``
tokens per slot; when a request outgrows it (or the scheduler says so)
the overflow prefix is shipped to creditor instances and decoding
continues with ``decode_step_dist`` — the DistAttention path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import DecodeState, decode_step, init_decode_state
from repro.models.prefill import decode_step_dist, prefill, write_slot
from repro.serving.request import Request, RequestState
from repro.serving.rmanager import RManager


def repack_ring(state: DecodeState, new_maxlen: int,
                n_keep: Optional[int] = None) -> DecodeState:
    """Convert a full prefill cache (max_len = T, identity layout) into a
    ring cache of ``new_maxlen`` holding the tail ``n_keep`` tokens."""
    T = int(state.lens[0])
    n = min(T, new_maxlen if n_keep is None else n_keep)
    k = state.kv_k[:, :, T - n:T]
    v = state.kv_v[:, :, T - n:T]
    slots = (T - n + np.arange(n)) % new_maxlen
    L, B = state.kv_k.shape[:2]
    shape = (L, B, new_maxlen) + state.kv_k.shape[3:]
    nk = jnp.zeros(shape, state.kv_k.dtype).at[:, :, slots].set(k)
    nv = jnp.zeros(shape, state.kv_v.dtype).at[:, :, slots].set(v)
    return DecodeState(nk, nv, state.lens, state.rec)


@dataclass
class CommStats:
    """Bytes moved, per category — feeds the Fig. 4/11/12 benchmarks."""
    kv_moved: int = 0            # KV block migration (overlapped)
    query_shipped: int = 0       # q + (o, m, l) merge traffic per step
    tokens_moved_steps: List[int] = field(default_factory=list)


class InstanceEngine:
    """One serving instance (model replica)."""

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_local_len: int = 256, pool_blocks: int = 1024,
                 block_size: int = 16, inst_id: int = 0,
                 capacity_factor: float = -1.0):
        self.params = params
        self.cfg = cfg
        self.inst_id = inst_id
        self.max_batch = max_batch
        self.max_local_len = max_local_len
        self.block_size = block_size
        self.rmanager = RManager(inst_id, pool_blocks, block_size)
        self.state = init_decode_state(cfg, max_batch, max_local_len)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.start = np.zeros(max_batch, np.int64)   # first local abs pos
        self.waiting: List[Request] = []
        self.hosted: Dict[int, Tuple[jnp.ndarray, jnp.ndarray]] = {}
        self.stats = CommStats()
        self._key = jax.random.PRNGKey(1234 + inst_id)
        self._can_pool = cfg.family in ("dense", "moe")
        # Remote spans per req_id: owner-side view (k, v arrays per
        # creditor, concatenated lazily at step time).
        self.remote: Dict[int, List[Tuple[int, jnp.ndarray, jnp.ndarray]]] \
            = {}
        # Cluster-installed callback: place an overflowing prefill prefix
        # on creditors. sink(req, k, v) -> list[(dst_inst, k, v)] | None.
        self.prefix_sink: Optional[Callable] = None

    # ----------------------------------------------------------------- #
    def submit(self, req: Request) -> None:
        req.state = RequestState.WAITING
        self.waiting.append(req)

    @property
    def running(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    @property
    def batch_size(self) -> int:
        return len(self.running)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    # ----------------------------------------------------------------- #
    def _admit_one(self) -> bool:
        if not self.waiting:
            return False
        slot = self._free_slot()
        if slot is None:
            return False
        req = self.waiting[0]
        T = len(req.prompt)
        # Admit with one block of ring headroom so the first decode writes
        # never evict live KV before a reactive move can run.
        cap = self.max_local_len - self.block_size
        n_local = min(T, cap)
        need_blocks = -(-n_local // self.block_size)
        if self.rmanager.pool.alloc.free_count < need_blocks:
            return False
        if T > cap and (not self._can_pool or self.prefix_sink is None):
            req.state = RequestState.FAILED      # cannot span: no KV pool
            self.waiting.pop(0)
            return True
        self.waiting.pop(0)

        tokens = jnp.asarray([req.prompt], jnp.int32)
        logits, full_state = prefill(self.params, self.cfg, tokens,
                                     max_len=T)
        if T > cap:
            # Ship the overflow prefix to creditors before decoding starts
            # (the paper's prefill-time spill).
            n_over = T - n_local
            spans = self.prefix_sink(req,
                                     full_state.kv_k[:, :, :n_over],
                                     full_state.kv_v[:, :, :n_over])
            if spans is None:                    # cluster-wide OOM
                req.state = RequestState.FAILED
                return True
            self.remote[req.req_id] = list(spans)
            nbytes = sum(int(k.size + v.size) * k.dtype.itemsize
                         for _, k, v in spans)
            self.stats.kv_moved += nbytes
            self.start[slot] = n_over
        else:
            self.start[slot] = 0
        req_state = repack_ring(full_state, self.max_local_len,
                                n_keep=n_local)
        self.state = write_slot(self.state, slot, req_state, self.cfg)
        self.rmanager.pool.append_tokens(req.req_id, n_local)
        self.rmanager.set_owner(req.req_id, True)
        req.slot = slot
        req.state = RequestState.RUNNING
        self.slots[slot] = req
        # First generated token comes from the prefill logits.
        self._emit(req, logits[0])
        return True

    def _emit(self, req: Request, logits: jnp.ndarray) -> None:
        if req.sampling.temperature <= 0.0:
            tok = int(jnp.argmax(logits))
        else:
            self._key, sub = jax.random.split(self._key)
            tok = int(jax.random.categorical(
                sub, logits.astype(jnp.float32) / req.sampling.temperature))
        req.output.append(tok)
        eos = req.sampling.eos_token
        if (len(req.output) >= req.sampling.max_new_tokens
                or (eos is not None and tok == eos)):
            self._finish(req)

    def _finish(self, req: Request) -> None:
        req.state = RequestState.FINISHED
        req.finish_time = time.monotonic()
        if req.slot is not None:
            self.slots[req.slot] = None
            self.start[req.slot] = 0
            req.slot = None
        self.rmanager.release_request(req.req_id)
        self.remote.pop(req.req_id, None)

    # ----------------------------------------------------------------- #
    def _gather_remote(self, reqs: List[Optional[Request]]):
        """Build padded [L, B, S_r, K, hd] remote arrays for this step."""
        cfg = self.cfg
        L = self.state.kv_k.shape[0]
        K, hd = cfg.num_kv_heads, cfg.head_dim
        spans = []
        for r in reqs:
            if r is None or r.req_id not in self.remote:
                spans.append(None)
                continue
            ks = [k for (_, k, _) in self.remote[r.req_id]]
            vs = [v for (_, _, v) in self.remote[r.req_id]]
            spans.append((jnp.concatenate(ks, 2), jnp.concatenate(vs, 2)))
        S_r = max([s[0].shape[2] for s in spans if s is not None],
                  default=0)
        S_r = max(S_r, 1)
        B = len(reqs)
        rk = jnp.zeros((L, B, S_r, K, hd), jnp.dtype(cfg.dtype))
        rv = jnp.zeros((L, B, S_r, K, hd), jnp.dtype(cfg.dtype))
        rlen = np.zeros(B, np.int32)
        for b, s in enumerate(spans):
            if s is None:
                continue
            n = s[0].shape[2]
            rk = rk.at[:, b, :n].set(s[0][:, 0])
            rv = rv.at[:, b, :n].set(s[1][:, 0])
            rlen[b] = n
        return rk, rv, jnp.asarray(rlen)

    def step(self) -> int:
        """Admit + one decode iteration. Returns #tokens generated."""
        while self._admit_one():
            pass
        running = [r for r in self.slots if r is not None]
        if not running:
            self.rmanager.batch_size = 0
            return 0

        tokens = np.zeros(self.max_batch, np.int32)
        active = np.zeros(self.max_batch, bool)
        for i, r in enumerate(self.slots):
            if r is not None:
                tokens[i] = r.output[-1] if r.output else r.prompt[-1]
                active[i] = True
        tokens = jnp.asarray(tokens)

        any_remote = any(r is not None and r.req_id in self.remote
                         for r in self.slots)
        if any_remote:
            rk, rv, rlen = self._gather_remote(self.slots)
            start = jnp.asarray(self.start, jnp.int32)
            logits, self.state = decode_step_dist(
                self.params, self.cfg, self.state, tokens, start, rk, rv,
                rlen)
            # Account the paper's per-step merge traffic: q + (o, m, l).
            H, hd = self.cfg.num_heads, self.cfg.head_dim
            L = self.cfg.num_layers
            n_span = sum(1 for r in self.slots
                         if r is not None and r.req_id in self.remote)
            self.stats.query_shipped += int(
                n_span * L * (H * hd * 2 + H * hd * 4 + 2 * H * 4))
        else:
            logits, self.state = decode_step(self.params, self.cfg,
                                             self.state, tokens)

        made = 0
        for i, r in enumerate(list(self.slots)):
            if r is None:
                continue
            self.rmanager.pool.append_tokens(r.req_id, 1)
            self._emit(r, logits[i])
            made += 1
        self.rmanager.batch_size = self.batch_size
        return made

    # --- KV movement (debtor side) ------------------------------------ #
    def extract_prefix_kv(self, req: Request, n_tokens: int):
        """Slice [start, start+n) KV out of the ring (before eviction)."""
        slot = req.slot
        s0 = int(self.start[slot])
        maxlen = self.max_local_len
        pos = s0 + np.arange(n_tokens)
        ring = pos % maxlen
        k = self.state.kv_k[:, slot:slot + 1, ring]
        v = self.state.kv_v[:, slot:slot + 1, ring]
        return k, v

    def ring_free_tokens(self, req: Request) -> int:
        slot = req.slot
        used = req.length - int(self.start[slot])
        return self.max_local_len - used

    def advance_start(self, req: Request, n_tokens: int) -> None:
        self.start[req.slot] += n_tokens
        n_blocks = n_tokens // self.block_size
        if n_blocks:
            self.rmanager.move_out_prefix(req.req_id, n_blocks)

    # --- creditor side -------------------------------------------------#
    def host_kv(self, req_id: int, k, v) -> None:
        if req_id in self.hosted:
            k0, v0 = self.hosted[req_id]
            k, v = jnp.concatenate([k0, k], 2), jnp.concatenate([v0, v], 2)
        self.hosted[req_id] = (k, v)

    def drop_hosted(self, req_id: int) -> None:
        self.hosted.pop(req_id, None)
        self.rmanager.release_request(req_id)
