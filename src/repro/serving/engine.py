"""Single-instance serving engine: continuous batching over a paged pool.

ORCA-style iteration-level scheduling: each ``step()`` admits waiting
requests into free slots (prefill), then runs ONE decode iteration for
all running slots. For poolable families (dense/moe) ALL serving KV
bytes live in the instance's device-resident block pool
``pool_k/pool_v: [L, num_blocks, block_size, K, hd]``, managed by the
``RManager``'s block allocator and addressed only through block tables:

  * prefill admission writes the local tail of the prompt's KV into
    freshly allocated blocks (the overflow prefix is spilled to creditor
    instances' pools via ``prefix_sink``),
  * each decode step appends the new token's KV into the request's tail
    block inside the jitted ``decode_step_paged``,
  * creditor-hosted spans are just blocks owned by ``req_id`` in the
    creditor's pool (``host_kv`` writes the rows; dropping them is a
    metadata release),
  * moving KV between instances copies pool rows and edits tables —
    shapes never change, so the decode step never retraces from growth.

``max_local_len`` survives as the per-request LOCAL QUOTA (the paper's
instance-local budget): when a request's local span approaches it the
cluster ships prefix blocks to a creditor and decoding continues with
the multi-rank paged step. Non-attention families (hybrid/ssm) keep the
dense ``DecodeState`` path — their recurrent state is O(1) per request
and never pools.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import DecodeState, decode_step, init_decode_state
from repro.models.prefill import (decode_step_paged, prefill, repack_ring,
                                  write_slot)
from repro.serving.kvpool import (build_local_tables, read_pool_rows,
                                  table_bucket, write_pool_rows)
from repro.serving.request import Request, RequestState
from repro.serving.rmanager import RManager


@dataclass
class CommStats:
    """Bytes moved, per category — feeds the Fig. 4/11/12 benchmarks."""
    kv_moved: int = 0            # KV block migration (overlapped)
    query_shipped: int = 0       # q + (o, m, l) merge traffic per step
    tokens_moved_steps: List[int] = field(default_factory=list)
    host_gather_s: float = 0.0   # host-side table/step-input build time
    decode_steps: int = 0


class InstanceEngine:
    """One serving instance (model replica)."""

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_local_len: int = 256, pool_blocks: int = 1024,
                 block_size: int = 16, inst_id: int = 0,
                 capacity_factor: float = -1.0):
        self.params = params
        self.cfg = cfg
        self.inst_id = inst_id
        self.max_batch = max_batch
        self.max_local_len = max_local_len
        self.block_size = block_size
        self.rmanager = RManager(inst_id, pool_blocks, block_size)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.waiting: List[Request] = []
        self.stats = CommStats()
        self._key = jax.random.PRNGKey(1234 + inst_id)
        self._can_pool = cfg.family in ("dense", "moe")
        if self._can_pool:
            assert max_local_len >= 2 * block_size, \
                "local quota must cover at least two blocks"
            L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
            dt = jnp.dtype(cfg.dtype)
            # THE serving KV store: every local or hosted byte lives here.
            self.pool_k = jnp.zeros((L, pool_blocks, block_size, K, hd), dt)
            self.pool_v = jnp.zeros((L, pool_blocks, block_size, K, hd), dt)
            self.state: Optional[DecodeState] = None
        else:
            self.pool_k = self.pool_v = None
            self.state = init_decode_state(cfg, max_batch, max_local_len)
        # Owner-side placement metadata: req_id -> creditor inst ids
        # hosting prefix spans (the KV itself is in THEIR pools).
        self.remote_insts: Dict[int, List[int]] = {}
        # Cluster-installed peer lookup (inst_id -> InstanceEngine) so the
        # decode step can read creditor pools directly.
        self.peers: Dict[int, "InstanceEngine"] = {}
        # Cluster-installed callback: place an overflowing prefill prefix
        # on creditors. sink(req, k, v) -> list[(dst_inst, n_tokens)] | None.
        self.prefix_sink: Optional[Callable] = None

    # ----------------------------------------------------------------- #
    def submit(self, req: Request) -> None:
        req.state = RequestState.WAITING
        self.waiting.append(req)

    @property
    def running(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    @property
    def batch_size(self) -> int:
        return len(self.running)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    # ----------------------------------------------------------------- #
    def _admit_one(self) -> bool:
        if not self.waiting:
            return False
        slot = self._free_slot()
        if slot is None:
            return False
        req = self.waiting[0]
        T = len(req.prompt)
        bs = self.block_size
        # Admit with one block of quota headroom so the first decode
        # appends never breach the local budget before a reactive move
        # can run. The spilled prefix is block-aligned so creditor spans
        # are always whole blocks.
        cap = self.max_local_len - bs
        n_over = 0 if T <= cap else -(-(T - cap) // bs) * bs
        n_local = T - n_over
        need_blocks = -(-n_local // bs)
        if self.rmanager.pool.alloc.free_count < need_blocks:
            return False
        if n_over and (not self._can_pool or self.prefix_sink is None):
            req.state = RequestState.FAILED      # cannot span: no KV pool
            self.waiting.pop(0)
            return True
        self.waiting.pop(0)

        tokens = jnp.asarray([req.prompt], jnp.int32)
        logits, full_state = prefill(self.params, self.cfg, tokens,
                                     max_len=T)
        if n_over:
            # Ship the overflow prefix to creditors before decoding
            # starts (the paper's prefill-time spill).
            spans = self.prefix_sink(req,
                                     full_state.kv_k[:, :, :n_over],
                                     full_state.kv_v[:, :, :n_over])
            if spans is None:                    # cluster-wide OOM
                req.state = RequestState.FAILED
                return True
            insts = []
            for dst, _ in spans:
                if dst not in insts:
                    insts.append(dst)
            self.remote_insts[req.req_id] = insts
            itemsize = jnp.dtype(self.cfg.dtype).itemsize
            self.stats.kv_moved += int(
                2 * full_state.kv_k[:, :, :n_over].size) * itemsize
        if self._can_pool:
            self.rmanager.pool.append_tokens(req.req_id, n_local)
            blocks = self.rmanager.pool.requests[req.req_id].blocks
            self.pool_k = write_pool_rows(self.pool_k, blocks,
                                          full_state.kv_k[:, 0, n_over:],
                                          bs)
            self.pool_v = write_pool_rows(self.pool_v, blocks,
                                          full_state.kv_v[:, 0, n_over:],
                                          bs)
        else:
            req_state = repack_ring(full_state, self.max_local_len,
                                    n_keep=min(n_local, self.max_local_len))
            self.state = write_slot(self.state, slot, req_state, self.cfg)
            self.rmanager.pool.append_tokens(req.req_id, n_local)
        self.rmanager.set_owner(req.req_id, True)
        req.slot = slot
        req.state = RequestState.RUNNING
        self.slots[slot] = req
        # First generated token comes from the prefill logits.
        self._emit(req, logits[0])
        return True

    def _emit(self, req: Request, logits: jnp.ndarray) -> None:
        if req.sampling.temperature <= 0.0:
            tok = int(jnp.argmax(logits))
        else:
            self._key, sub = jax.random.split(self._key)
            tok = int(jax.random.categorical(
                sub, logits.astype(jnp.float32) / req.sampling.temperature))
        req.output.append(tok)
        eos = req.sampling.eos_token
        if (len(req.output) >= req.sampling.max_new_tokens
                or (eos is not None and tok == eos)):
            self._finish(req)

    def _finish(self, req: Request) -> None:
        req.state = RequestState.FINISHED
        req.finish_time = time.monotonic()
        self._release_slot(req)

    def _fail(self, req: Request) -> None:
        req.state = RequestState.FAILED
        self._release_slot(req)

    def _release_slot(self, req: Request) -> None:
        if req.slot is not None:
            self.slots[req.slot] = None
            req.slot = None
        self.rmanager.release_request(req.req_id)
        self.remote_insts.pop(req.req_id, None)

    # ----------------------------------------------------------------- #
    def _step_paged(self) -> Optional[jnp.ndarray]:
        """One decode iteration over the pool path. Returns logits."""
        pool = self.rmanager.pool
        bs = self.block_size
        t0 = time.perf_counter()
        # Reserve this step's token in each request's tail block. A
        # failed append means the pool is exhausted: reject loudly,
        # never corrupt (paper: reject when pool exhausted).
        for r in list(self.slots):
            if r is not None and not pool.append_tokens(r.req_id, 1):
                self._fail(r)
        running = self.running
        if not running:
            return None
        B, NB = self.max_batch, pool.alloc.num_blocks
        tokens = np.zeros(B, np.int32)
        lens = np.zeros(B, np.int32)
        wblk = np.full(B, NB, np.int32)      # NB = out of range => dropped
        woff = np.zeros(B, np.int32)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            tokens[i] = r.output[-1] if r.output else r.prompt[-1]
            lens[i] = r.length - 1           # abs position of the new token
            rb = pool.requests[r.req_id]
            wblk[i] = rb.blocks[-1]
            woff[i] = rb.tail_tokens - 1
        insts = sorted({i for r in running
                        for i in self.remote_insts.get(r.req_id, ())})
        rank_pools = [pool] + [self.peers[i].rmanager.pool for i in insts]
        req_ids = [r.req_id if r is not None else -1 for r in self.slots]
        needed = max((len(p.requests[rid].blocks)
                      for p in rank_pools for rid in req_ids
                      if rid in p.requests), default=1)
        tables, tails = build_local_tables(rank_pools, req_ids,
                                           table_bucket(needed))
        remote_pools = tuple((self.peers[i].pool_k, self.peers[i].pool_v)
                             for i in insts)
        self.stats.host_gather_s += time.perf_counter() - t0
        self.stats.decode_steps += 1

        logits, self.pool_k, self.pool_v = decode_step_paged(
            self.params, self.cfg, tokens, lens, self.pool_k, self.pool_v,
            tables, tails, wblk, woff, remote_pools=remote_pools)

        # Account the paper's per-step merge traffic — q + (o, m, l) —
        # once per (request, creditor) span entry, matching the per-rank
        # partial exchanges a real deployment would make.
        H, hd = self.cfg.num_heads, self.cfg.head_dim
        L = self.cfg.num_layers
        entries = sum(len(self.remote_insts.get(r.req_id, ()))
                      for r in running)
        self.stats.query_shipped += int(
            entries * L * (H * hd * 2 + H * hd * 4 + 2 * H * 4))
        return logits

    def step(self) -> int:
        """Admit + one decode iteration. Returns #tokens generated."""
        while self._admit_one():
            pass
        if not self.running:
            self.rmanager.batch_size = 0
            return 0

        if self._can_pool:
            logits = self._step_paged()
            if logits is None:
                self.rmanager.batch_size = 0
                return 0
        else:
            tokens = np.zeros(self.max_batch, np.int32)
            for i, r in enumerate(self.slots):
                if r is not None:
                    tokens[i] = r.output[-1] if r.output else r.prompt[-1]
            logits, self.state = decode_step(self.params, self.cfg,
                                             self.state,
                                             jnp.asarray(tokens))
            for r in self.running:
                self.rmanager.pool.append_tokens(r.req_id, 1)

        made = 0
        for i, r in enumerate(list(self.slots)):
            if r is None:
                continue
            self._emit(r, logits[i])
            made += 1
        self.rmanager.batch_size = self.batch_size
        return made

    # --- KV movement (debtor side) ------------------------------------ #
    def local_tokens(self, req: Request) -> int:
        return self.rmanager.pool.tokens_of(req.req_id)

    def local_free_tokens(self, req: Request) -> int:
        """Quota slots left AFTER the pending token's append."""
        return self.max_local_len - self.local_tokens(req) - 1

    def extract_prefix_kv(self, req: Request, n_blocks: int):
        """Read the OLDEST n full blocks' rows out of the local pool."""
        blocks = self.rmanager.pool.requests[req.req_id].blocks[:n_blocks]
        k = read_pool_rows(self.pool_k, blocks, self.block_size)
        v = read_pool_rows(self.pool_v, blocks, self.block_size)
        return k[:, None], v[:, None]        # [L, 1, n*bs, K, hd]

    # --- creditor side -------------------------------------------------#
    def host_kv(self, req_id: int, blocks: List[int], k, v) -> None:
        """Write an arriving span's rows into already-committed blocks.

        k/v: [L, 1, n, K, hd] with n == len(blocks) * block_size (spans
        are always whole blocks).
        """
        self.pool_k = write_pool_rows(self.pool_k, blocks, k[:, 0],
                                      self.block_size)
        self.pool_v = write_pool_rows(self.pool_v, blocks, v[:, 0],
                                      self.block_size)

    def drop_hosted(self, req_id: int) -> None:
        """Release a hosted span — pure metadata; rows are reused later."""
        self.rmanager.release_request(req_id)
