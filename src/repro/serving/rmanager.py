"""rManager: per-instance local manager (paper §6.1).

Owns the instance's block pool, answers try_move_kvcache reservations
FCFS, emits delta heartbeats, and executes movement instructions. The
actual KV bytes live in the engine's device pool tensors; every row of
those tensors is addressed exclusively through the block ids this
metadata hands out, so a stale gManager plan can never corrupt state —
a reservation that never commits is just cancelled numbers.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Set

from repro.serving.kvpool import RankKVPool
from repro.serving.protocol import Heartbeat, RequestPlacementEntry


class RManager:
    """Per-instance resource manager: the paper's rManager role.

    Owns the instance's ``RankKVPool`` view, tracks which requests this
    rank OWNS (debtor) vs merely hosts (creditor), and emits the
    delta-compressed ``Heartbeat`` stream Algorithm 1 plans from.
    """

    def __init__(self, inst_id: int, num_blocks: int, block_size: int,
                 pool: Optional[RankKVPool] = None):
        self.inst_id = inst_id
        # In global-pool mode the cluster hands every rManager its slice
        # of ``GlobalKVPool.ranks`` — the SAME allocator object the
        # sharded step's table builders read, so placement metadata is
        # identical whether steps run in-process or under shard_map.
        self.pool = pool if pool is not None else RankKVPool(num_blocks,
                                                             block_size)
        self.block_size = block_size
        self._seq = 0
        self._last_reported: Dict[int, RequestPlacementEntry] = {}
        self._owned: Set[int] = set()       # req_ids this instance owns
        self.batch_size = 0
        # Prefix-cache hooks (cluster-installed when caching is on):
        # evict_hook(n) frees up to n unpinned cached frames on demand;
        # cache_blocks_fn() reports how many such frames exist — the
        # heartbeat carries it so Algorithm 1 treats cached-but-unpinned
        # memory as reclaimable creditor capacity.
        self.evict_hook: Optional[Callable[[int], int]] = None
        self.cache_blocks_fn: Optional[Callable[[], int]] = None

    @property
    def effective_free(self) -> int:
        """Allocatable blocks counting evictable cache replicas."""
        free = self.pool.alloc.free_count
        if self.cache_blocks_fn is not None:
            free += self.cache_blocks_fn()
        return free

    # --- placement metadata ------------------------------------------- #
    def set_owner(self, req_id: int, owned: bool = True) -> None:
        """Mark/unmark this rank as ``req_id``'s owner (debtor)."""
        (self._owned.add if owned else self._owned.discard)(req_id)

    def entries(self) -> List[RequestPlacementEntry]:
        """Current placement entries (one per request with blocks)."""
        out = []
        for rid, rb in self.pool.requests.items():
            if not rb.blocks:
                continue
            out.append(RequestPlacementEntry(
                req_id=rid, inst_id=self.inst_id,
                num_blocks=len(rb.blocks), local=rid in self._owned))
        return out

    # --- heartbeat (delta unless full resync requested) ---------------- #
    def heartbeat(self, full: bool = False) -> Heartbeat:
        """Build the next heartbeat (delta unless ``full`` resync)."""
        self._seq += 1
        cur = {e.req_id: e for e in self.entries()}
        if full:
            send = list(cur.values())
            removed: List[int] = []
        else:
            send = [e for rid, e in cur.items()
                    if self._last_reported.get(rid) != e]
            removed = [rid for rid in self._last_reported if rid not in cur]
        self._last_reported = cur
        return Heartbeat(
            inst_id=self.inst_id, seq=self._seq, full=full, entries=send,
            batch_size=self.batch_size,
            mem_blocks_total=self.pool.alloc.num_blocks,
            mem_blocks_used=self.pool.alloc.used_count,
            removed_req_ids=removed,
            cache_blocks=(self.cache_blocks_fn()
                          if self.cache_blocks_fn is not None else 0))

    # --- try_move_kvcache: FCFS reservation on the DESTINATION --------- #
    def try_move_kvcache(self, req_id: int, num_blocks: int) -> bool:
        """Called by a SOURCE instance before shipping KV here. When the
        pool is short, unpinned prefix-cache replicas are evicted on
        demand (spilling to the host tier) before refusing."""
        if self.pool.alloc.reserve(num_blocks):
            return True
        if self.evict_hook is not None:
            self.evict_hook(num_blocks - self.pool.alloc.free_count)
            return self.pool.alloc.reserve(num_blocks)
        return False

    def commit_move_in(self, req_id: int, num_blocks: int,
                       at_front: bool = True) -> Optional[List[int]]:
        """Receive KV previously reserved. Returns local block ids."""
        self.pool.alloc.reserved -= num_blocks
        blocks = self.pool.adopt_blocks(req_id, num_blocks,
                                        at_front=at_front)
        return blocks

    def cancel_move_in(self, num_blocks: int) -> None:
        """Roll back a refused move's capacity reservation."""
        self.pool.alloc.cancel_reservation(num_blocks)

    def move_out_prefix(self, req_id: int, num_blocks: int) -> int:
        """Release the oldest n blocks of req (after shipping). Returns
        the number actually released."""
        popped = self.pool.pop_prefix_blocks(req_id, num_blocks)
        return len(popped)

    def is_hosting(self, req_id: int) -> bool:
        """True iff this rank holds blocks for a request it does NOT own
        (i.e. it is a creditor for that request)."""
        return req_id in self.pool.requests and req_id not in self._owned

    def release_request(self, req_id: int) -> None:
        """Free every block and ownership record of ``req_id``."""
        self.pool.release(req_id)
        self._owned.discard(req_id)
