from repro.core.online_softmax import (
    combine, empty_partial, finalize, merge_partials,
    micro_attention_decode, micro_attention_prefill,
)
from repro.core.attention import (
    dist_attention_decode, dist_attention_prefill,
    full_attention_decode, full_attention_prefill,
)
from repro.core.distattn import (
    distattn_decode_paged, gather_local_kv, local_mask_from_table,
    merge_over_axes,
)

__all__ = [
    "combine", "empty_partial", "finalize", "merge_partials",
    "micro_attention_decode", "micro_attention_prefill",
    "dist_attention_decode", "dist_attention_prefill",
    "full_attention_decode", "full_attention_prefill",
    "distattn_decode_paged", "gather_local_kv", "local_mask_from_table",
    "merge_over_axes",
]
