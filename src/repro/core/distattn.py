"""Mesh-parallel DistAttention: MicroAttention partials merged by collectives.

This is the paper's Eq. 2-3 mapped onto TPU collectives inside
``shard_map``: every rank computes a MicroAttention partial over whatever
KV blocks it *locally* holds (possibly none — empty partials are the monoid
identity and merge away), then the partials are reduced with one ``pmax``
and two ``psum``s over the mesh axes that can hold KV.  Per-step traffic is
the query + per-head scalars + one value vector — never the KVCache.
"""
from __future__ import annotations

from typing import Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.online_softmax import finalize, micro_attention_decode

AxisNames = Union[str, Sequence[str]]


def merge_over_axes(o: jax.Array, m: jax.Array, l: jax.Array,
                    axis_names: AxisNames):
    """Collective LSE-merge of per-rank partials (paper Eq. 3).

    Must be called inside shard_map. Returns the *normalized* output.
    Traffic: pmax(m) + psum(l') + psum(o') = (2 * |m| + |o|) elements.
    """
    m_g = jax.lax.pmax(m, axis_names)
    scale = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_g))
    l_g = jax.lax.psum(l * scale, axis_names)
    o_g = jax.lax.psum(o * scale[..., None], axis_names)
    return finalize(o_g, l_g)


def gather_local_kv(pool_k: jax.Array, pool_v: jax.Array,
                    local_table: jax.Array):
    """Materialize [B, S_local, K, D] KV from a paged pool.

    pool_k/pool_v: [num_blocks_local, block_size, K, D] — this rank's pool.
    local_table:   [B, max_local_blocks] int32 — local block ids, -1 = none.

    Invalid entries gather block 0 and are masked by the caller via
    ``local_mask_from_table``.
    """
    nb, bs, K, D = pool_k.shape
    safe = jnp.maximum(local_table, 0)
    k = pool_k[safe].reshape(local_table.shape[0], -1, K, D)
    v = pool_v[safe].reshape(local_table.shape[0], -1, K, D)
    return k, v


def local_mask_from_table(local_table: jax.Array, block_size: int,
                          last_block_len: jax.Array | None = None):
    """[B, max_local_blocks*block_size] bool validity mask for gathered KV.

    ``last_block_len``: optional [B] — number of valid tokens in each
    request's final (partially filled) block; the fill block id must be
    the lexicographically-last valid entry of the row.
    """
    B, MB = local_table.shape
    valid_block = (local_table >= 0)
    mask = jnp.repeat(valid_block, block_size, axis=1)
    if last_block_len is not None:
        # Positions within each block.
        within = jnp.tile(jnp.arange(block_size), MB)[None, :]
        n_valid = valid_block.sum(axis=1)                       # [B]
        block_idx = jnp.repeat(jnp.arange(MB)[None, :], B, 0)
        block_idx = jnp.repeat(block_idx, block_size, axis=1)
        is_last = block_idx == (n_valid - 1)[:, None]
        mask = mask & jnp.where(is_last, within < last_block_len[:, None], True)
    return mask


def distattn_decode_paged(
    q: jax.Array,             # [B, H, D] (replicated or per-rank batch slice)
    pool_k: jax.Array,        # [NB_local, bs, K, D]
    pool_v: jax.Array,
    local_table: jax.Array,   # [B, MB_local] int32, -1 padded
    last_block_len: jax.Array,  # [B] tokens valid in final local block
    axis_names: AxisNames,
    *,
    scale: float | None = None,
    backend: str = "xla",
    interpret: bool = True,
):
    """Full paged DistAttention decode step for one layer, inside shard_map.

    Each rank attends over its local pool blocks (Pallas kernel or jnp
    reference), then partials merge across ``axis_names``.
    """
    bs = pool_k.shape[1]
    if backend == "pallas":
        from repro.kernels.ops import paged_micro_attention
        o, m, l = paged_micro_attention(q, pool_k, pool_v, local_table,
                                        last_block_len, scale=scale,
                                        interpret=interpret)
    else:
        k, v = gather_local_kv(pool_k, pool_v, local_table)
        mask = local_mask_from_table(local_table, bs, last_block_len)
        o, m, l = micro_attention_decode(q, k, v, mask, scale=scale)
    out = merge_over_axes(o, m, l, axis_names)
    return out.astype(q.dtype)
