"""Paper-§7.4 baselines: RingAttention, head-partition TP, and ship-KV.

All are written as shard_map bodies over a named axis so they run on real
meshes (tests use 8 fake CPU devices) and so their communication volume is
visible in lowered HLO for the Fig. 11 benchmark.
"""
from __future__ import annotations

import jax

from repro.core.online_softmax import (
    combine, empty_partial, finalize,
    micro_attention_decode, micro_attention_prefill,
)


def ring_attention_prefill(q, k, v, q_pos, kv_pos, kv_valid, axis_name,
                           *, scale=None):
    """RingAttention (Liu et al.): KV blocks rotate, queries stay.

    Inside shard_map: q [B,T,H,D] local query block; k/v [B,S,K,D] local KV
    block; positions absolute. Per step, each rank ships its whole KV block
    to the next rank (the communication the paper's Fig. 11 charges Ring
    with), accumulating online-softmax partials locally.
    """
    P = jax.lax.psum(1, axis_name)
    B, T, H, D = q.shape
    acc = empty_partial((B, T, H, D), (B, T, H))
    perm = [(i, (i + 1) % P) for i in range(P)]

    def body(i, carry):
        acc, k, v, kv_pos, kv_valid = carry
        part = micro_attention_prefill(q, k, v, q_pos, kv_pos, kv_valid,
                                       scale=scale)
        acc = combine(acc, part)
        # Rotate the KV block (+ its metadata) around the ring.
        k = jax.lax.ppermute(k, axis_name, perm)
        v = jax.lax.ppermute(v, axis_name, perm)
        kv_pos = jax.lax.ppermute(kv_pos, axis_name, perm)
        kv_valid = jax.lax.ppermute(kv_valid, axis_name, perm)
        return acc, k, v, kv_pos, kv_valid

    acc, *_ = jax.lax.fori_loop(0, P, body, (acc, k, v, kv_pos, kv_valid))
    return finalize(acc[0], acc[2]).astype(q.dtype)


def tp_head_attention_decode(q_local, k_local, v_local, mask, *, scale=None):
    """Megatron-style TP attention: KV sharded by heads, sequence whole.

    Inside shard_map: q_local [B,H/P,D], k/v_local [B,S,K/P,D] — every rank
    holds the FULL sequence for its head group (this is what forces KV-head
    replication when kv_heads < P, the memory cost DistAttention removes).
    No collective here; the o-proj outside is row-parallel (one psum).
    """
    o, _, l = micro_attention_decode(q_local, k_local, v_local, mask,
                                     scale=scale)
    return finalize(o, l).astype(q_local.dtype)


def ship_kv_decode(q, k_local, v_local, mask_local, axis_name, *, scale=None):
    """Strawman of paper Fig. 4(a): gather the distributed KV to every rank
    and run full attention locally. Communication = the whole KVCache."""
    k = jax.lax.all_gather(k_local, axis_name, axis=1, tiled=True)
    v = jax.lax.all_gather(v_local, axis_name, axis=1, tiled=True)
    mask = jax.lax.all_gather(mask_local, axis_name, axis=1, tiled=True)
    o, _, l = micro_attention_decode(q, k, v, mask, scale=scale)
    return finalize(o, l).astype(q.dtype)


def distattn_decode(q, k_local, v_local, mask_local, axis_name, *, scale=None):
    """DistAttention over the same layout as ``ship_kv_decode`` for an
    apples-to-apples Fig. 11 comparison: communication = q-scalars + merge."""
    from repro.core.distattn import merge_over_axes
    o, m, l = micro_attention_decode(q, k_local, v_local, mask_local,
                                     scale=scale)
    return merge_over_axes(o, m, l, axis_name).astype(q.dtype)
