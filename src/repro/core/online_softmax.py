"""Online-softmax partials and the DistAttention merge (paper Eq. 1-3).

A *partial* is the triple ``(o, m, l)`` over some slice of the sequence:

    m = max_i s_i                      (running max of attention scores)
    l = sum_i exp(s_i - m)             (paper's e_j)
    o = sum_i exp(s_i - m) * v_i       (paper's MA_j, unnormalized)

Partials form a commutative monoid under ``combine`` — the identity is
``(0, -inf, 0)`` — which is what lets DistAttention evaluate attention over
arbitrary sub-blocks of the KVCache placed on arbitrary devices and merge
with only per-head scalars + one value-vector of traffic (paper Fig. 4b).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Partial = Tuple[jax.Array, jax.Array, jax.Array]  # (o, m, l)

NEG_INF = float("-inf")


def empty_partial(out_shape, stat_shape, dtype=jnp.float32) -> Partial:
    """Identity element: contributes nothing to the merge."""
    return (
        jnp.zeros(out_shape, dtype),
        jnp.full(stat_shape, NEG_INF, dtype),
        jnp.zeros(stat_shape, dtype),
    )


def _safe_scale(m: jax.Array, m_new: jax.Array) -> jax.Array:
    """exp(m - m_new), defined as 0 when both are -inf (empty slices)."""
    scale = jnp.exp(m - m_new)
    return jnp.where(jnp.isneginf(m), 0.0, scale)


def combine(a: Partial, b: Partial) -> Partial:
    """Associative+commutative merge of two partials (paper Eq. 3, pairwise)."""
    o_a, m_a, l_a = a
    o_b, m_b, l_b = b
    m = jnp.maximum(m_a, m_b)
    sa = _safe_scale(m_a, m)
    sb = _safe_scale(m_b, m)
    l = l_a * sa + l_b * sb
    o = o_a * sa[..., None] + o_b * sb[..., None]
    return o, m, l


def merge_partials(o: jax.Array, m: jax.Array, l: jax.Array,
                   axis: int = 0) -> Partial:
    """Merge a stacked set of partials along ``axis`` (paper Eq. 3).

    o: [..., P, ..., D] stacked unnormalized outputs, m/l: stats without D.
    Returns a single (o, m, l).
    """
    m_g = jnp.max(m, axis=axis)
    scale = _safe_scale(m, jnp.expand_dims(m_g, axis))
    l_g = jnp.sum(l * scale, axis=axis)
    o_g = jnp.sum(o * scale[..., None], axis=axis)
    return o_g, m_g, l_g


def merge_partials_collective(o: jax.Array, m: jax.Array, l: jax.Array,
                              axis_name) -> Partial:
    """Merge per-shard partials across a mesh axis (paper Eq. 3, collective).

    The shard_map counterpart of ``merge_partials``: each shard holds ONE
    partial (its MicroAttention over locally-resident KV blocks) and only
    the per-head scalars ``(m, l)`` plus the value-vector ``o`` cross the
    interconnect — pmax for the running max, psum for the rescaled sums.
    ``axis_name`` may be a single mesh axis or a tuple of axes.
    """
    m_g = jax.lax.pmax(m, axis_name)
    scale = _safe_scale(m, m_g)
    l_g = jax.lax.psum(l * scale, axis_name)
    o_g = jax.lax.psum(o * scale[..., None], axis_name)
    return o_g, m_g, l_g


def finalize(o: jax.Array, l: jax.Array) -> jax.Array:
    """Normalize a merged partial into the attention output.

    Empty attention (l == 0, e.g. fully-masked slice) yields zeros rather
    than NaN so padded requests stay inert.
    """
    denom = jnp.where(l == 0.0, 1.0, l)
    return o / denom[..., None]


def micro_attention_decode(
    q: jax.Array,            # [B, H, D]
    k: jax.Array,            # [B, S, K, D]
    v: jax.Array,            # [B, S, K, D]
    mask: jax.Array,         # [B, S] bool — True where the KV slot is valid
    *,
    scale: float | None = None,
) -> Partial:
    """MicroAttention for one decode step over a slice of KV (paper Eq. 2).

    Supports MHA/GQA/MQA: H query heads grouped over K kv heads.
    Returns (o [B,H,D] f32 unnormalized, m [B,H] f32, l [B,H] f32).
    """
    B, H, D = q.shape
    K = k.shape[2]
    G = H // K
    if scale is None:
        scale = D ** -0.5
    # Keep k/v in their storage dtype; accumulate in f32 via the dot's
    # preferred_element_type — avoids materializing f32 copies of the
    # whole KV (measured 17.8 MB/layer/device at 500k ctx; §Perf-1).
    qc = q.astype(k.dtype).reshape(B, K, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qc, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # [B, K, G]
    p = jnp.exp(s - jnp.where(jnp.isneginf(m), 0.0, m)[..., None])
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)                                   # [B, K, G]
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(k.dtype), v,
                   preferred_element_type=jnp.float32)        # [B,K,G,D]
    return (o.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))


def micro_attention_prefill(
    q: jax.Array,            # [B, T, H, D]  queries at positions q_pos
    k: jax.Array,            # [B, S, K, D]  a KV slice at positions kv_pos
    v: jax.Array,            # [B, S, K, D]
    q_pos: jax.Array,        # [B, T] int32 absolute positions of queries
    kv_pos: jax.Array,       # [B, S] int32 absolute positions of KV slots
    kv_valid: jax.Array,     # [B, S] bool
    *,
    scale: float | None = None,
    window: int = 0,         # >0: sliding-window (local) attention
) -> Partial:
    """Causal MicroAttention over a KV slice for a block of queries.

    Returns (o [B,T,H,D], m [B,T,H], l [B,T,H]) in f32, mergeable across
    KV slices with ``merge_partials``/``combine``.
    """
    B, T, H, D = q.shape
    K = k.shape[2]
    G = H // K
    if scale is None:
        scale = D ** -0.5
    qc = q.astype(k.dtype).reshape(B, T, K, G, D)
    s = jnp.einsum("btkgd,bskd->btkgs", qc, k,
                   preferred_element_type=jnp.float32) * scale
    ok = (kv_pos[:, None, :] <= q_pos[:, :, None]) & kv_valid[:, None, :]
    if window:
        ok = ok & (kv_pos[:, None, :] > q_pos[:, :, None] - window)
    s = jnp.where(ok[:, :, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - jnp.where(jnp.isneginf(m), 0.0, m)[..., None])
    p = jnp.where(ok[:, :, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("btkgs,bskd->btkgd", p.astype(k.dtype), v,
                   preferred_element_type=jnp.float32)
    return (o.reshape(B, T, H, D), m.reshape(B, T, H), l.reshape(B, T, H))
