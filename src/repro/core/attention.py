"""DistAttention as a composable module (single-device semantics).

``dist_attention_decode`` / ``dist_attention_prefill`` evaluate attention
over an arbitrary partition of the KV sequence dimension and merge the
MicroAttention partials — mathematically equivalent to full attention
(paper §4).  The mesh-parallel version (partials merged with collectives)
lives in ``repro.core.distattn``; the Pallas kernel in ``repro.kernels``.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.online_softmax import (combine, empty_partial, finalize,
                                       micro_attention_decode,
                                       micro_attention_prefill)


def full_attention_decode(q, k, v, mask, *, scale=None) -> jax.Array:
    """Reference single-shot decode attention (paper Eq. 1). q:[B,H,D]."""
    o, _, l = micro_attention_decode(q, k, v, mask, scale=scale)
    return finalize(o, l).astype(q.dtype)


def dist_attention_decode(
    q: jax.Array,                                  # [B, H, D]
    kv_parts: Sequence[Tuple[jax.Array, jax.Array, jax.Array]],
    *,
    scale=None,
) -> jax.Array:
    """Decode attention over an arbitrary sequence partition of the KV.

    ``kv_parts`` is a list of (k, v, mask) slices — the paper's MA blocks,
    conceptually living on different instances. Equivalent to
    ``full_attention_decode`` on the concatenated KV.
    """
    B, H, D = q.shape
    acc = empty_partial((B, H, D), (B, H))
    for k, v, mask in kv_parts:
        acc = combine(acc, micro_attention_decode(q, k, v, mask, scale=scale))
    return finalize(acc[0], acc[2]).astype(q.dtype)


def full_attention_prefill(q, k, v, *, q_offset=0, kv_valid=None, scale=None,
                           window=0):
    """Reference causal prefill attention. q:[B,T,H,D], k/v:[B,S,K,D].

    ``q_offset`` positions queries at [offset, offset+T) against KV at
    [0, S) — used for chunked prefill where KV includes the past.
    """
    B, T = q.shape[:2]
    S = k.shape[1]
    q_pos = q_offset + jnp.arange(T, dtype=jnp.int32)[None, :].repeat(B, 0)
    kv_pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    if kv_valid is None:
        kv_valid = jnp.ones((B, S), dtype=bool)
    o, _, l = micro_attention_prefill(q, k, v, q_pos, kv_pos, kv_valid,
                                      scale=scale, window=window)
    return finalize(o, l).astype(q.dtype)


def dist_attention_prefill(
    q: jax.Array,                                  # [B, T, H, D]
    kv_parts: Sequence[Tuple[jax.Array, jax.Array, jax.Array, jax.Array]],
    q_pos: jax.Array,                              # [B, T]
    *,
    scale=None,
) -> jax.Array:
    """Causal prefill over a partition of KV slices.

    ``kv_parts``: list of (k, v, kv_pos, kv_valid) — positions are absolute
    so slices may live anywhere in the sequence and in any order.
    """
    B, T, H, D = q.shape
    acc = empty_partial((B, T, H, D), (B, T, H))
    for k, v, kv_pos, kv_valid in kv_parts:
        part = micro_attention_prefill(q, k, v, q_pos, kv_pos, kv_valid,
                                       scale=scale)
        acc = combine(acc, part)
    return finalize(acc[0], acc[2]).astype(q.dtype)


def sliding_window_mask_decode(kv_pos, cur_pos, window):
    """Valid-mask for local attention at decode: last ``window`` tokens."""
    return (kv_pos > cur_pos[:, None] - window) & (kv_pos <= cur_pos[:, None])
