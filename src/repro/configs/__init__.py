"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, reduce_config

_ARCH_MODULES: Dict[str, str] = {
    "kimi-k2-1t-a32b":   "repro.configs.kimi_k2_1t_a32b",
    "qwen2-moe-a2.7b":   "repro.configs.qwen2_moe_a2_7b",
    "starcoder2-15b":    "repro.configs.starcoder2_15b",
    "mistral-nemo-12b":  "repro.configs.mistral_nemo_12b",
    "olmo-1b":           "repro.configs.olmo_1b",
    "qwen3-0.6b":        "repro.configs.qwen3_0_6b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "chameleon-34b":     "repro.configs.chameleon_34b",
    "musicgen-medium":   "repro.configs.musicgen_medium",
    "xlstm-350m":        "repro.configs.xlstm_350m",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str, **kw) -> ModelConfig:
    return reduce_config(get_config(arch_id), **kw)


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "ARCH_IDS",
    "get_config", "get_smoke_config", "reduce_config",
]
