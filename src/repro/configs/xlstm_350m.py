"""xLSTM-350M. [arXiv:2405.04517]

24L d_model=1024 4H d_ff=0 vocab=50304. sLSTM + mLSTM blocks (one sLSTM
per 8 blocks, rest mLSTM, proj factor 2.0). No KV cache exists — mLSTM
carries a fixed-size matrix memory per head — so DistAttention is
inapplicable (DESIGN.md §Arch-applicability); decode state is O(1).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50_304,
    norm_type="layernorm",
    activation="gelu",
    positional="none",
    slstm_every=8,
    mlstm_proj_factor=2.0,
)
