"""Mistral-Nemo-12B (128k ctx). [hf:mistralai/Mistral-Nemo-Base-2407]

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128
(explicit: attention inner dim 4096 != d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=131_072,
    norm_type="rmsnorm",
    activation="swiglu",
    rope_theta=1_000_000.0,
)
