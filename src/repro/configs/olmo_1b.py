"""OLMo-1B. [arXiv:2402.00838; hf]

16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304.
Non-parametric LayerNorm (no scale/bias), SwiGLU, RoPE, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50_304,
    norm_type="nonparametric_ln",
    activation="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
