"""Qwen1.5-MoE-A2.7B. [hf:Qwen/Qwen1.5-MoE-A2.7B]

24L d_model=2048 16H (MHA kv=16) expert d_ff=1408 vocab=151936,
60 routed experts top-4 + 4 shared experts (shared width 4x1408=5632).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=151_936,
    num_experts=60,
    num_shared_experts=4,
    top_k=4,
    moe_d_ff=1408,
    norm_type="rmsnorm",
    activation="swiglu",
    rope_theta=1_000_000.0,
)
