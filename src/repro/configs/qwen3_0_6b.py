"""Qwen3-0.6B. [hf:Qwen/Qwen3-0.6B]

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
QK-norm (RMSNorm on per-head q/k before RoPE), head_dim=128 explicit,
tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151_936,
    norm_type="rmsnorm",
    activation="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
