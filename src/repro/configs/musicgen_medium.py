"""MusicGen-medium. [arXiv:2306.05284; hf]

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.
Decoder-only over EnCodec tokens; the EnCodec frontend is a STUB —
``input_specs`` feeds precomputed frame embeddings. LayerNorm + GELU,
sinusoidal positions.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="dense",
    modality="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    norm_type="layernorm",
    activation="gelu",
    positional="sinusoidal",
)
