"""RecurrentGemma-9B (Griffin). [arXiv:2402.19427]

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.
Block pattern 1 local-attention per 2 RG-LRU blocks; window 2048; GeGLU.
Recurrent state is O(1) in sequence length -> DistAttention KV pooling is
inapplicable (see DESIGN.md §Arch-applicability); local attention layers
still use the MicroAttention kernel within their bounded window.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    norm_type="rmsnorm",
    activation="geglu",
    rope_theta=10_000.0,
    block_pattern=("rglru", "rglru", "attn"),
    local_window=2048,
    lru_width=4096,
)
