"""StarCoder2-15B. [arXiv:2402.19173; hf]

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152. LayerNorm + GELU
MLP (gpt-bigcode style), RoPE.  kv=4 < TP=16 makes this the showcase for
DistAttention-over-model-axis replacing head-TP (paper Fig. 11).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24_576,
    vocab_size=49_152,
    norm_type="layernorm",
    activation="gelu",
    rope_theta=100_000.0,
)
