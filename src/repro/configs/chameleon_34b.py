"""Chameleon-34B. [arXiv:2405.09818]

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
Early-fusion mixed-modal decoder over VQ image tokens + text tokens; the
image tokenizer frontend is a STUB — ``input_specs`` feeds precomputed
patch-token embeddings. QK-norm as in the paper.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    modality="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22_016,
    vocab_size=65_536,
    norm_type="rmsnorm",
    activation="swiglu",
    qk_norm=True,
    rope_theta=10_000.0,
)
