"""Kimi K2 — trillion-param MoE. [arXiv:2501.kimi2; paper-table]

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840,
MoE 384 routed experts top-8 + 1 shared expert, first layer dense.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,                 # 7168 / 64
    d_ff=18432,                   # dense FFN for the first_k_dense layer
    vocab_size=163_840,
    num_experts=384,
    num_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    first_k_dense=1,
    norm_type="rmsnorm",
    activation="swiglu",
    rope_theta=50_000.0,
)
