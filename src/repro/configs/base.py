"""Config dataclasses for models, shapes, and the serving/training runtime.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig`` instances in ``SHAPES``.  Reduced
("smoke") variants for CPU tests are derived with ``reduce_config`` so they
preserve the structural family (MoE routing, hybrid layer pattern, sLSTM
placement) while shrinking every dimension.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # "dense" | "moe" | "hybrid" | "ssm"
    modality: str = "text"           # "text" | "vlm" | "audio"
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                # explicit; not always d_model // num_heads
    d_ff: int = 0                    # dense FFN width (0 for pure-SSM archs)
    vocab_size: int = 0

    # --- MoE ---
    num_experts: int = 0             # routed experts (0 => dense FFN)
    num_shared_experts: int = 0      # always-on experts (Qwen-MoE / Kimi style)
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert FFN width
    first_k_dense: int = 0           # leading layers that use a dense FFN

    # --- normalization / activation / positional ---
    norm_type: str = "rmsnorm"       # "rmsnorm" | "layernorm" | "nonparametric_ln"
    activation: str = "swiglu"       # "swiglu" | "geglu" | "gelu"
    qk_norm: bool = False
    positional: str = "rope"         # "rope" | "sinusoidal" | "none"
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # --- hybrid (RecurrentGemma): repeating block pattern ---
    # e.g. ("rglru", "rglru", "attn"): one attention layer per two recurrent.
    block_pattern: Tuple[str, ...] = ()
    local_window: int = 0            # sliding-window size for local attention
    lru_width: int = 0               # RG-LRU recurrent width (0 => d_model)

    # --- ssm (xLSTM): which layer indices are sLSTM (rest mLSTM) ---
    slstm_every: int = 0             # i % slstm_every == slstm_every-1 => sLSTM
    mlstm_proj_factor: float = 2.0

    dtype: str = "bfloat16"

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    def layer_kind(self, i: int) -> str:
        """Block type at layer index i: 'attn' | 'rglru' | 'mlstm' | 'slstm'."""
        if self.family == "ssm":
            if self.slstm_every and (i % self.slstm_every == self.slstm_every - 1):
                return "slstm"
            return "mlstm"
        if self.block_pattern:
            return self.block_pattern[i % len(self.block_pattern)]
        return "attn"

    # --- sizing helpers (used by the perf model and roofline) ----------- #
    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """KV-cache bytes per token across ALL layers (0 for O(1)-state archs)."""
        per_layer = 2 * self.num_kv_heads * self.head_dim * bytes_per_el
        n_attn = sum(1 for i in range(self.num_layers) if self.layer_kind(i) == "attn")
        return per_layer * n_attn

    def param_count(self) -> int:
        """Total parameter count (approximate for ssm/hybrid internals)."""
        d, hd = self.d_model, self.head_dim
        n_emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = n_emb
        glu = self.activation in ("swiglu", "geglu")
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += d * self.num_heads * hd * 2            # q, o
                total += d * self.num_kv_heads * hd * 2         # k, v
            elif kind == "rglru":
                w = self.lru_width or d
                total += 2 * d * w + w * d + 3 * w              # in x2, out, gates
            elif kind in ("mlstm", "slstm"):
                pf = self.mlstm_proj_factor if kind == "mlstm" else 4.0 / 3.0
                up = int(d * pf)
                total += 2 * d * up + up * d + 4 * up           # up/gate/down + gates
            # FFN
            if kind in ("attn", "rglru"):
                if self.is_moe and i >= self.first_k_dense:
                    n_e = self.num_experts + self.num_shared_experts
                    per = (3 if glu else 2) * d * self.moe_d_ff
                    total += n_e * per + d * self.num_experts   # + router
                elif self.d_ff:
                    total += (3 if glu else 2) * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k + shared experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        glu = self.activation in ("swiglu", "geglu")
        per = (3 if glu else 2) * d * self.moe_d_ff
        n_moe_layers = self.num_layers - self.first_k_dense
        inactive = (self.num_experts - self.top_k) * per * n_moe_layers
        return self.param_count() - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                        # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    "train",   4_096,   256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768,  32),
    "decode_32k":  ShapeConfig("decode_32k",  "decode",  32_768,  128),
    "long_500k":   ShapeConfig("long_500k",   "decode",  524_288, 1),
}


def reduce_config(cfg: ModelConfig, *, layers: Optional[int] = None) -> ModelConfig:
    """Shrink a config to CPU-smoke size while preserving family structure."""
    pat = len(cfg.block_pattern) or 1
    n_layers = layers if layers is not None else max(2, pat)
    if cfg.block_pattern:
        n_layers = max(n_layers, pat)          # at least one full pattern
    if cfg.slstm_every:
        n_layers = max(n_layers, cfg.slstm_every)
    heads = 4
    kv = max(1, heads * cfg.num_kv_heads // max(1, cfg.num_heads))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        num_experts=min(cfg.num_experts, 8),
        num_shared_experts=min(cfg.num_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        moe_d_ff=0 if cfg.moe_d_ff == 0 else 32,
        first_k_dense=min(cfg.first_k_dense, 1),
        local_window=0 if cfg.local_window == 0 else 32,
        lru_width=0 if cfg.lru_width == 0 else 64,
        slstm_every=cfg.slstm_every,
    )
