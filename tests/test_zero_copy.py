"""Zero-copy KV hot path (ISSUE-4 acceptance).

(a) Donation guards: the jitted pool updaters and the paged decode /
    prefill steps DONATE the pool tensors — on backends that honor
    donation the returned array reuses the donated buffer (no
    [L, NB, bs, K, hd] copy per step) and the stale handle is dead;
    outputs stay token-identical to the dense pre-donation oracle.
(b) The Pallas prefill-chunk paged partial matches the pure-jnp oracle
    in ``kernels/ref.py`` across chunk sizes (and the jnp fallback).
(c) Async (overlapped) vs serial movement is a pure scheduling choice:
    the decoded token streams are identical, only the sync policy
    differs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels.ops import paged_prefill_attention
from repro.kernels.ref import paged_prefill_micro_attention_ref
from repro.models.model import decode_step, init_params
from repro.models.prefill import prefill
from repro.serving import (Cluster, InstanceEngine, Request, RequestState,
                           SamplingParams, ServingConfig)
from repro.serving.engine import buffer_ptr
from repro.serving.kvpool import scatter_pool_rows, write_pool_rows

_SETUPS = {}


def _setup(arch="olmo-1b"):
    if arch not in _SETUPS:
        cfg = get_smoke_config(arch)
        _SETUPS[arch] = (cfg, init_params(jax.random.PRNGKey(0), cfg))
    return _SETUPS[arch]


def _greedy_reference(params, cfg, prompt, n_new):
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
    return out


def _donation_supported() -> bool:
    """True iff this backend reuses a donated buffer in place."""
    f = jax.jit(lambda x: x + 1, donate_argnums=0)
    x = jnp.zeros((256,), jnp.float32)
    p = buffer_ptr(x)
    y = f(x)
    return p is not None and buffer_ptr(y) == p


# ------------------------------------------------------------------ #
# (b) Pallas prefill-chunk partial == ref oracle, all chunk sizes
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("chunk", [3, 8, 32])
def test_prefill_partial_kernel_matches_oracle(chunk):
    key = jax.random.PRNGKey(11)
    NB, bs, K, G, D, MB = 12, 8, 2, 2, 24, 4      # D off the 128 lane
    H = K * G
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (chunk, H, D))
    pool_k = jax.random.normal(kk, (NB, bs, K, D))
    pool_v = jax.random.normal(kv, (NB, bs, K, D))
    for table, tail in [([0, 3, 5, -1], 5), ([7, -1, -1, -1], 8),
                        ([2, 4, 6, 8], 2)]:
        table = jnp.asarray(table, jnp.int32)
        nblk = jnp.sum(table >= 0)
        ref = paged_prefill_micro_attention_ref(
            q, pool_k, pool_v, table, nblk, jnp.asarray(tail, jnp.int32))
        got_pl = paged_prefill_attention(
            q, pool_k, pool_v, table, jnp.asarray(tail, jnp.int32),
            backend="pallas", interpret=True)
        got_np = paged_prefill_attention(
            q, pool_k, pool_v, table, jnp.asarray(tail, jnp.int32),
            backend="jnp")
        for r, a, b in zip(ref, got_pl, got_np):
            np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                       atol=1e-5, rtol=1e-5)
            np.testing.assert_allclose(np.asarray(b), np.asarray(r),
                                       atol=1e-5, rtol=1e-5)


def test_prefill_partial_kernel_empty_table_is_identity():
    """A rank with zero coverage contributes the merge identity."""
    q = jax.random.normal(jax.random.PRNGKey(0), (4, 4, 16))
    pool = jnp.ones((6, 8, 2, 16))
    table = jnp.full((4,), -1, jnp.int32)
    o, m, l = paged_prefill_attention(q, pool, pool, table,
                                      jnp.asarray(8, jnp.int32),
                                      backend="pallas", interpret=True)
    assert float(jnp.abs(o).sum()) == 0.0
    assert bool(jnp.all(jnp.isneginf(m)))
    assert float(jnp.abs(l).sum()) == 0.0


# ------------------------------------------------------------------ #
# (a) Donation guards
# ------------------------------------------------------------------ #
def test_pool_writers_donate_and_kill_stale_handle():
    if not _donation_supported():
        pytest.skip("backend does not honor donation")
    L, NB, bs, K, hd = 2, 6, 4, 2, 8
    pool = jnp.zeros((L, NB, bs, K, hd), jnp.float32)
    rows = jax.random.normal(jax.random.PRNGKey(1), (L, 7, K, hd))
    p0 = buffer_ptr(pool)
    new = write_pool_rows(pool, [3, 1], rows, bs)
    assert buffer_ptr(new) == p0, "write_pool_rows copied the pool"
    assert pool.is_deleted(), "stale pool handle survived donation"
    p1 = buffer_ptr(new)
    new2 = scatter_pool_rows(new, [2, 2], [0, 1], rows[:, :2])
    assert buffer_ptr(new2) == p1, "scatter_pool_rows copied the pool"
    assert new.is_deleted()


def test_decode_steps_never_copy_the_pool_and_match_oracle():
    """The whole serving hot path — streaming admission chunks + every
    decode step — runs without one pool-tensor copy, and the generated
    stream equals the dense pre-donation oracle."""
    cfg, params = _setup()
    rng = np.random.default_rng(21)
    prompt = list(rng.integers(0, cfg.vocab_size, 21))
    n_new = 12
    ref = _greedy_reference(params, cfg, prompt, n_new)

    eng = InstanceEngine(params, cfg, max_batch=2, max_local_len=64,
                         pool_blocks=32, block_size=8, prefill_chunk=8)
    req = Request(prompt=prompt,
                  sampling=SamplingParams(max_new_tokens=n_new))
    eng.submit(req)
    for _ in range(40):
        if req.done:
            break
        eng.step()
    assert req.state == RequestState.FINISHED
    assert req.output == ref, "donated hot path diverged from oracle"
    assert eng.stats.decode_steps >= n_new - 1
    if _donation_supported():
        assert eng.stats.pool_copy_steps == 0, \
            f"{eng.stats.pool_copy_steps}/{eng.stats.decode_steps} " \
            "decode steps copied the pool despite donation"


def test_sampling_key_is_threaded_not_reuploaded():
    """The PRNG key is split device-side and donated: stochastic
    sampling stays reproducible across engines, and on donating
    backends the key buffer is reused in place every step."""
    cfg, params = _setup()
    rng = np.random.default_rng(5)
    prompt = list(rng.integers(0, cfg.vocab_size, 6))

    def run():
        eng = InstanceEngine(params, cfg, max_batch=2, max_local_len=64,
                             pool_blocks=32, block_size=8,
                             prefill_chunk=8, inst_id=0)
        req = Request(prompt=prompt, sampling=SamplingParams(
            max_new_tokens=8, temperature=0.8))
        eng.submit(req)
        ptrs = set()
        for _ in range(20):
            if req.done:
                break
            eng.step()
            p = buffer_ptr(eng._key)
            if p is not None:
                ptrs.add(p)
        return req.output, ptrs

    out_a, ptrs_a = run()
    out_b, _ = run()
    assert out_a == out_b, "device-side key threading broke determinism"
    if _donation_supported():
        assert len(ptrs_a) == 1, \
            "sampling key was re-uploaded instead of donated in place"


# ------------------------------------------------------------------ #
# (c) Async vs serial movement: token-identical, only sync policy
# ------------------------------------------------------------------ #
def test_async_and_serial_movement_are_token_identical():
    # float32 so LSE-merge rounding cannot flip near-tie argmaxes of the
    # random-init smoke model (same convention as the striped-scheduling
    # exactness tests — the comparison is token identity, not numerics).
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("olmo-1b"),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(13)
    prompts = [list(rng.integers(0, cfg.vocab_size, 40)),
               list(rng.integers(0, cfg.vocab_size, 24))]
    n_new = 16
    refs = [_greedy_reference(params, cfg, p, n_new) for p in prompts]

    outs, movers = [], []
    for overlap in (False, True):
        cl = Cluster(params, cfg, ServingConfig.smoke(
            max_batch=2, pool_blocks=32, async_movement=overlap))
        reqs = [Request(prompt=p,
                        sampling=SamplingParams(max_new_tokens=n_new))
                for p in prompts]
        for r in reqs:
            cl.submit(r)
        cl.run_until_done(max_steps=400)
        assert all(r.state == RequestState.FINISHED for r in reqs)
        outs.append([r.output for r in reqs])
        moved = sum(len(e.stats.tokens_moved_steps)
                    for e in cl.engines.values())
        movers.append(moved)
        assert cl.stager.staged > 0, "movement never went through staging"
        if overlap:
            # Overlap mode: strictly fewer sync points than copy chains.
            assert cl.stager.synced < cl.stager.staged
        else:
            assert cl.stager.synced == cl.stager.staged
    assert movers[0] > 0 and movers[1] > 0, "scenario moved no KV"
    assert outs[0] == outs[1], "sync policy changed the token stream"
    assert outs[1] == refs, "movement path diverged from dense oracle"
