"""Launch-layer regression: every cell kind lowers+compiles on a mini
multi-pod mesh; compressed cross-pod grad sync is exact mod int8
(subprocess: needs its own fake-device count)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_launch_cells_and_grad_sync():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "helpers",
                                      "launch_check.py")],
        env=env, capture_output=True, text=True, timeout=1800)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ALL OK" in r.stdout
