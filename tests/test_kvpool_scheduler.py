"""Unit + property tests: block allocator invariants, perf model shape,
Algorithm-1 scheduler behaviour, heartbeat protocol."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

from repro.configs import get_config
from repro.serving.kvpool import BlockAllocator, RankKVPool
from repro.serving.perfmodel import InstancePerfModel
from repro.serving.scheduler import GreedyScheduler, InstanceView
from repro.serving.gmanager import GManager
from repro.serving.rmanager import RManager


# ------------------------------------------------------------------ #
# Allocator invariants (hypothesis)
# ------------------------------------------------------------------ #
if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(ops=st.lists(st.tuples(st.sampled_from(["alloc", "free",
                                                   "reserve", "cancel"]),
                                  st.integers(1, 8)), max_size=60))
    def test_allocator_never_double_allocates(ops):
        a = BlockAllocator(32, 16)
        live = {}
        rid = 0
        for op, n in ops:
            if op == "alloc":
                got = a.alloc(n, rid)
                if got is not None:
                    for b in got:
                        assert b not in set().union(*live.values()) \
                            if live else True
                        assert 0 <= b < 32
                    live[rid] = set(got)
                    rid += 1
            elif op == "free" and live:
                k = sorted(live)[0]
                a.free(sorted(live.pop(k)))
            elif op == "reserve":
                a.reserve(n)
            elif op == "cancel":
                a.cancel_reservation(n)
            allocated = set().union(*live.values()) if live else set()
            assert len(allocated) == a.used_count
            assert a.free_count >= 0
            assert a.free_count + a.reserved + a.used_count == 32
else:
    @pytest.mark.skip(reason="property tests need hypothesis "
                      "(pip install -r requirements-dev.txt)")
    def test_allocator_property_suite_requires_hypothesis():
        """Visible placeholder: the allocator invariant property test
        above was not collected."""


def test_pool_append_and_prefix_pop():
    p = RankKVPool(num_blocks=8, block_size=4)
    assert p.append_tokens(1, 10)          # 3 blocks, tail=2
    assert p.tokens_of(1) == 10
    assert p.alloc.used_count == 3
    popped = p.pop_prefix_blocks(1, 2)
    assert len(popped) == 2
    assert p.tokens_of(1) == 2             # 1 block, tail 2
    assert p.append_tokens(1, 2)           # fills tail, no new block
    assert p.alloc.used_count == 1
    p.release(1)
    assert p.alloc.used_count == 0


def test_pool_rejects_when_full():
    p = RankKVPool(num_blocks=2, block_size=4)
    assert p.append_tokens(1, 8)
    assert not p.append_tokens(2, 1)


# ------------------------------------------------------------------ #
# Perf model (paper Fig. 2 / Fig. 7 shapes)
# ------------------------------------------------------------------ #
def test_perfmodel_batch_saturation():
    m = InstancePerfModel(get_config("olmo-1b"))
    tps = [m.tps(b, [500] * b) for b in (1, 8, 64, 256, 512)]
    assert all(t2 > t1 for t1, t2 in zip(tps, tps[1:3]))  # ramps up
    # Saturation: doubling batch far past critical intensity gains little.
    assert tps[-1] / tps[-2] < 1.7


def test_perfmodel_debtor_creditor_aggregate_peak():
    """Fig. 7(c): aggregate TPS rises (debtor batch grows into the freed
    memory) then falls (creditor keeps paying the hosted-KV time)."""
    cfg = get_config("olmo-1b")
    m = InstancePerfModel(cfg)
    long_len = 1_000_000                   # the paper's Fig. 7 debtor
    spare = 300_000                        # creditor's surplus KV tokens
    agg = []
    for off in range(0, 1_000_001, 50_000):
        # Freed debtor memory admits extra 500-token requests, capped at
        # compute saturation (paper Fig. 2b plateau).
        extra = min(off // 2_000, 240)
        debtor = m.tps(1 + extra, [long_len] + [500] * extra,
                       offloaded_tokens=off)
        # Past its surplus, the creditor evicts its own requests to host
        # more KV — the Fig. 7(b) "steeper decline".
        c_beta = 128 - max(0, off - spare) // 5_000
        creditor = m.tps(c_beta, [5_000] * c_beta, hosted_tokens=off)
        agg.append(debtor + creditor)
    peak = int(np.argmax(agg))
    assert agg[peak] > agg[0] * 1.05       # moving blocks helps
    assert agg[-1] < agg[peak]             # and overdoing it hurts


# ------------------------------------------------------------------ #
# Algorithm 1
# ------------------------------------------------------------------ #
def _view(iid, batch, used, total, reqs, hosted=0):
    return InstanceView(inst_id=iid, batch_size=batch,
                        mem_blocks_total=total, mem_blocks_used=used,
                        requests=reqs, hosted_tokens=hosted)


def test_scheduler_moves_from_debtor_to_creditor():
    cfg = get_config("olmo-1b")
    bs = 512
    sched = GreedyScheduler(InstancePerfModel(cfg), block_size=bs,
                            beta_thres=8, mem_util_thres=0.5)
    debtor = _view(0, 2, 95, 100, {7: (bs * 90, 90, True),
                                   8: (bs * 5, 5, True)})
    creditor = _view(1, 32, 10, 100, {9: (bs * 10, 10, True)})
    moves = sched.plan([debtor, creditor])
    assert moves, "expected at least one move"
    assert all(m.src == 0 for m in moves)
    assert all(leg.dst == 1 for m in moves for leg in m.legs)
    assert all(m.req_id == 7 for m in moves)   # longest request picked
    total = sum(m.num_blocks for m in moves)
    assert 0 < total <= 89                     # keeps the live tail local


def test_scheduler_plan_does_not_mutate_views():
    """plan() works on copies: the gManager's heartbeat-fed views stay
    reusable across planning rounds."""
    cfg = get_config("olmo-1b")
    bs = 512
    sched = GreedyScheduler(InstancePerfModel(cfg), block_size=bs,
                            beta_thres=8, mem_util_thres=0.5)
    debtor = _view(0, 2, 95, 100, {7: (bs * 90, 90, True)})
    creditor = _view(1, 32, 10, 100, {9: (bs * 10, 10, True)})
    moves = sched.plan([debtor, creditor])
    assert moves
    assert debtor.mem_blocks_used == 95
    assert debtor.requests[7] == (bs * 90, 90, True)
    assert debtor.offloaded_tokens == 0 and debtor.req_spans == {}
    assert creditor.mem_blocks_used == 10 and creditor.hosted_tokens == 0
    # Re-planning from the same views gives the same plan.
    again = sched.plan([debtor, creditor])
    assert [(m.req_id, m.src, [(leg.dst, leg.num_blocks)
                               for leg in m.legs]) for m in moves] == \
        [(m.req_id, m.src, [(leg.dst, leg.num_blocks)
                            for leg in m.legs]) for m in again]


def test_scheduler_stripes_across_small_creditors():
    """A movable prefix larger than any single creditor's free space is
    placed across several creditors in ONE plan (multi-leg)."""
    cfg = get_config("mistral-nemo-12b")
    bs = 512
    sched = GreedyScheduler(InstancePerfModel(cfg, chips=8), block_size=bs,
                            beta_thres=8, mem_util_thres=0.96)
    nblk = 2200
    debtor = _view(0, 2, nblk - 50, nblk, {7: (bs * 2000, 2000, True),
                                           8: (bs * 150, 150, True)})
    creds = [_view(i + 1, 16, nblk - 100, nblk,
                   {100 + i: (bs * 16, 16, True)}) for i in range(4)]
    moves = sched.plan([debtor] + creds)
    assert moves and moves[0].req_id == 7
    assert len(moves[0].legs) >= 2, "expected a striped multi-leg plan"
    # No leg over-commits its creditor's free blocks.
    for leg in moves[0].legs:
        assert leg.num_blocks <= 100
    # Striped plan moves more than any single creditor could hold.
    assert moves[0].num_blocks > 100


def test_scheduler_never_makes_instance_both_roles():
    cfg = get_config("olmo-1b")
    sched = GreedyScheduler(InstancePerfModel(cfg), block_size=16,
                            beta_thres=64, mem_util_thres=0.9)
    # Everyone qualifies as debtor AND creditor by thresholds.
    views = [_view(i, 4, 10, 100, {i * 10: (800, 50, True)})
             for i in range(4)]
    moves = sched.plan(views)
    srcs = {m.src for m in moves}
    dsts = {leg.dst for m in moves for leg in m.legs}
    assert not (srcs & dsts)


def test_scheduler_respects_creditor_capacity():
    cfg = get_config("olmo-1b")
    sched = GreedyScheduler(InstancePerfModel(cfg), block_size=16,
                            beta_thres=8, mem_util_thres=0.5)
    debtor = _view(0, 1, 100, 100, {1: (16 * 100, 100, True)})
    creditor = _view(1, 32, 97, 100, {2: (160, 10, True)})
    moves = sched.plan([debtor, creditor])
    assert sum(m.num_blocks for m in moves) <= 3


def test_scheduler_reclaims_stressed_creditor():
    """A creditor past the memory threshold while hosting another
    instance's span gets a reclaim plan: the span goes back to its owner
    (headroom permitting) or sideways to a calm creditor."""
    cfg = get_config("olmo-1b")
    bs = 512
    sched = GreedyScheduler(InstancePerfModel(cfg), block_size=bs,
                            beta_thres=8, mem_util_thres=0.8)
    owner = _view(0, 2, 40, 100, {7: (bs * 60, 40, True)})
    owner.offloaded_tokens = bs * 20
    owner.req_spans = {7: {1: 20}}
    host = _view(1, 32, 95, 100, {7: (bs * 20, 20, False),
                                  9: (bs * 60, 60, True)},
                 hosted=bs * 20)
    calm = _view(2, 32, 10, 100, {10: (bs * 10, 10, True)})
    moves = sched.plan([owner, host, calm])
    recl = [m for m in moves if m.kind == "reclaim"]
    assert recl, "expected a reclaim plan for the stressed host"
    m = recl[0]
    assert m.req_id == 7 and m.src == 1
    assert sum(leg.num_blocks for leg in m.legs) == 20
    assert all(leg.dst in (0, 2) for leg in m.legs)


# ------------------------------------------------------------------ #
# Protocol: heartbeats, deltas, failover resync
# ------------------------------------------------------------------ #
def test_heartbeat_delta_encoding():
    rm = RManager(0, num_blocks=16, block_size=4)
    rm.pool.append_tokens(1, 8)
    rm.set_owner(1)
    hb1 = rm.heartbeat(full=True)
    assert len(hb1.entries) == 1 and hb1.entries[0].num_blocks == 2
    hb2 = rm.heartbeat()                       # nothing changed
    assert not hb2.entries and not hb2.removed_req_ids
    rm.pool.append_tokens(1, 8)
    hb3 = rm.heartbeat()
    assert len(hb3.entries) == 1 and hb3.entries[0].num_blocks == 4
    rm.release_request(1)
    hb4 = rm.heartbeat()
    assert hb4.removed_req_ids == [1]


def test_gmanager_requires_full_on_new_instance_and_seq_gap():
    cfg = get_config("olmo-1b")
    gm = GManager(InstancePerfModel(cfg), block_size=4)
    rm = RManager(0, 16, 4)
    rm.pool.append_tokens(1, 8)
    assert not gm.on_heartbeat(rm.heartbeat(), now=0.0)   # delta first: no
    assert gm.on_heartbeat(rm.heartbeat(full=True), now=0.1)
    rm.heartbeat()                             # this delta gets "lost"
    assert not gm.on_heartbeat(rm.heartbeat(), now=0.2)   # seq gap
    assert gm.on_heartbeat(rm.heartbeat(full=True), now=0.3)


def test_gmanager_failover_rebuilds_from_full_heartbeats():
    cfg = get_config("olmo-1b")
    rms = [RManager(i, 16, 4) for i in range(3)]
    rms[0].pool.append_tokens(5, 12)
    rms[0].set_owner(5)
    rms[1].pool.append_tokens(5, 8)            # creditor slice of req 5
    gm2 = GManager(InstancePerfModel(cfg), block_size=4)   # new gManager
    for rm in rms:
        assert gm2.on_heartbeat(rm.heartbeat(full=True), now=1.0)
    assert gm2.owner_of(5) == 0
    assert set(gm2.requests_touching(1)) == {5}


def test_gmanager_liveness_timeout():
    cfg = get_config("olmo-1b")
    gm = GManager(InstancePerfModel(cfg), block_size=4,
                  heartbeat_timeout=1.0)
    rm = RManager(0, 16, 4)
    gm.on_heartbeat(rm.heartbeat(full=True), now=0.0)
    assert gm.check_liveness(now=0.5) == []
    assert gm.check_liveness(now=2.0) == [0]


def test_try_move_fcfs_rejection():
    rm = RManager(0, num_blocks=4, block_size=4)
    assert rm.try_move_kvcache(1, 3)
    assert not rm.try_move_kvcache(2, 2)       # only 1 left unreserved
    assert rm.try_move_kvcache(2, 1)
    got = rm.commit_move_in(1, 3)
    assert got is not None and len(got) == 3
