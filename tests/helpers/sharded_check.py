import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")
"""Subprocess helper: mesh-level serve_decode_step must reproduce the
single-device decode logits exactly, for BOTH pool layouts (tp_head and
seq_model) and an adversarial block placement. Exit 0 on success."""
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

from repro.configs import get_smoke_config
from repro.models.model import decode_step, init_params
from repro.models.prefill import prefill
from repro.serving.sharded_step import ServeLayout, serve_decode_step
from repro.distributed.sharding import param_specs, validate_divisibility


def check(arch: str, pool_axes, rng_seed=0, variant="baseline"):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(rng_seed)
    params = init_params(key, cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    R, T = 4, 21                       # ragged: partial tail block
    bs = 8
    tokens_hist = jax.random.randint(key, (R, T), 0, cfg.vocab_size)
    new_tok = jax.random.randint(jax.random.fold_in(key, 1), (R,), 0,
                                 cfg.vocab_size)

    # Reference: single-device dense-cache decode.
    _, st = prefill(params, cfg, tokens_hist, max_len=T + 4)
    ref_logits, _ = decode_step(params, cfg, st, new_tok)

    # Build the paged pool with an adversarial placement: request r's
    # block j lives on shard (r + j) % NP.
    L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    NP = int(np.prod([dict(zip(mesh.axis_names,
                               mesh.devices.shape))[a]
                      for a in pool_axes]))
    nblocks = -(-T // bs)
    per_shard = R * nblocks            # generous
    NB = per_shard
    pool_k = np.zeros((L, NP, NB, bs, K, hd), np.float32)
    pool_v = np.zeros_like(pool_k)
    MB = nblocks + 1
    tables = -np.ones((NP, R, MB), np.int32)
    tails = np.full((NP, R), bs, np.int32)
    next_free = np.zeros(NP, np.int32)
    kv_k = np.asarray(st.kv_k, np.float32)   # [L, R, maxlen, K, hd]
    kv_v = np.asarray(st.kv_v, np.float32)
    slot_of = {}
    for r in range(R):
        cnt = {}
        for j in range(nblocks):
            p = (r + j) % NP
            blk = int(next_free[p]); next_free[p] += 1
            c = cnt.get(p, 0); cnt[p] = c + 1
            tables[p, r, c] = blk
            lo, hi = j * bs, min((j + 1) * bs, T)
            pool_k[:, p, blk, :hi - lo] = kv_k[:, r, lo:hi]
            pool_v[:, p, blk, :hi - lo] = kv_v[:, r, lo:hi]
            slot_of[(r, j)] = (p, blk)
            if hi == T:
                tails[p, r] = hi - lo if hi - lo else bs
    # Tail-append target: last block has room (T % bs != 0).
    wblk = np.full((NP, R), NB, np.int32)    # dump by default
    woff = np.zeros((NP, R), np.int32)
    for r in range(R):
        p, blk = slot_of[(r, nblocks - 1)]
        wblk[p, r] = blk
        woff[p, r] = T % bs
        tails[p, r] += 1                     # include the new token
    nblk = (tables >= 0).sum(axis=2).astype(np.int32)

    layout = ServeLayout(batch_axes=("data",), pool_axes=pool_axes)
    pshapes = jax.eval_shape(lambda: params)
    pspecs = validate_divisibility(
        param_specs(cfg, pshapes, fsdp=False), pshapes, mesh)
    pool_spec = NamedSharding(mesh, P(None, pool_axes))
    itab = NamedSharding(mesh, P(pool_axes))
    bsh = NamedSharding(mesh, P("data"))

    jitted = jax.jit(
        lambda pr, pk, pv, tb, nb, tl, wb, wo, tk, ln: serve_decode_step(
            pr, cfg, layout, pk, pv, tb, nb, tl, wb, wo, tk, ln,
            capacity_factor=-1.0, return_logits=True),
        in_shardings=(
            jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                         is_leaf=lambda x: isinstance(x, P)),
            pool_spec, pool_spec, itab, itab, itab, itab, itab, bsh, bsh),
    )
    dt = jnp.dtype(cfg.dtype)
    with mesh:
        logits, pk_new, pv_new = jitted(
            params, jnp.asarray(pool_k, dt), jnp.asarray(pool_v, dt),
            jnp.asarray(tables), jnp.asarray(nblk), jnp.asarray(tails),
            jnp.asarray(wblk), jnp.asarray(woff),
            new_tok, jnp.full((R,), T, jnp.int32))

    got = np.asarray(logits, np.float32)
    want = np.asarray(ref_logits, np.float32)
    np.testing.assert_allclose(got, want, atol=5e-2, rtol=5e-2)

    # The new token's KV must have landed in the right tail slots.
    pk_new = np.asarray(pk_new, np.float32)
    wrote = 0
    for r in range(R):
        p, blk = slot_of[(r, nblocks - 1)]
        assert np.abs(pk_new[:, p, blk, T % bs]).sum() > 0
        wrote += 1
    assert wrote == R
    print(f"OK {arch} pool_axes={pool_axes} NP={NP}")


if __name__ == "__main__":
    check("olmo-1b", ("data",))              # tp_head (kv % model == 0)
    check("qwen3-0.6b", ("data", "model"))   # seq_model (kv=2 < 4)
    check("qwen2-moe-a2.7b", ("data",))      # MoE + EP
    print("ALL OK")
