import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")
"""Subprocess helper: the mesh-sharded GLOBAL KV pool must generate the
same greedy tokens as the per-instance cluster AND the dense-cache
oracle, dense + moe, with a mid-stream StripedMove relocating blocks
between rank slices of the one pool tensor. Exit 0 on success."""
import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

from repro.configs import get_smoke_config
from repro.models.model import decode_step, init_params
from repro.models.prefill import prefill
from repro.serving import (Cluster, Request, SamplingParams,
                           ServingConfig)
from repro.serving.sharded_step import ServeLayout


def greedy_ref(params, cfg, prompt, n_new):
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
    return out


def run_cluster(params, cfg, prompts, n_new, *, n_inst, global_pool,
                mesh=None, layout=None):
    cl = Cluster(params, cfg,
                 ServingConfig.smoke(n_instances=n_inst, max_batch=2,
                                     pool_blocks=32,
                                     global_pool=global_pool),
                 mesh=mesh, layout=layout)
    reqs = [Request(prompt=p, sampling=SamplingParams(max_new_tokens=n_new))
            for p in prompts]
    for r in reqs:
        cl.submit(r)
    cl.run_until_done(max_steps=400)
    assert all(r.done for r in reqs), [r.state for r in reqs]
    moved = sum(e.stats.kv_moved for e in cl.engines.values())
    copies = sum(e.stats.pool_copy_steps for e in cl.engines.values())
    return [r.output for r in reqs], moved, copies


def check(arch, n_inst, pool_axes, mesh_shape):
    # float32: the three implementations reassociate the LSE merge
    # differently, and greedy argmax must not flip on rounding noise.
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    # 40 tokens > max_local_len=32 forces creditor striping at admission
    # AND reactive StripedMoves mid-decode (= intra-tensor slice copies
    # between rank shards in global mode).
    prompts = [list(rng.integers(0, cfg.vocab_size, size=40)),
               list(rng.integers(0, cfg.vocab_size, size=9))]
    n_new = 12
    refs = [greedy_ref(params, cfg, p, n_new) for p in prompts]

    base, moved, _ = run_cluster(params, cfg, prompts, n_new,
                                 n_inst=n_inst, global_pool=False)
    assert base == refs, f"{arch}: per-instance cluster vs oracle"
    assert moved > 0, f"{arch}: expected mid-stream KV movement"

    outs, moved, copies = run_cluster(params, cfg, prompts, n_new,
                                      n_inst=n_inst, global_pool=True)
    assert outs == refs, f"{arch}: global pool (vmap) vs oracle"
    assert moved > 0
    assert copies == 0, f"{arch}: global-pool donation broken ({copies})"

    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    layout = ServeLayout(batch_axes=("data",), pool_axes=pool_axes)
    outs, moved, _ = run_cluster(params, cfg, prompts, n_new,
                                 n_inst=n_inst, global_pool=True,
                                 mesh=mesh, layout=layout)
    assert outs == refs, f"{arch}: global pool (shard_map) vs oracle"
    assert moved > 0
    print(f"OK {arch} n_inst={n_inst} pool_axes={pool_axes} "
          f"mesh={mesh_shape}")


if __name__ == "__main__":
    check("olmo-1b", 2, ("data",), (2, 1))          # 2 ranks / 2 shards
    check("olmo-1b", 4, ("data", "model"), (2, 2))  # 4 ranks / 2x2 mesh
    check("qwen2-moe-a2.7b", 2, ("data",), (2, 1))  # MoE + global pool
    print("ALL OK")
