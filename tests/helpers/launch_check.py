import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")
"""Subprocess helper: the launch layer must lower+compile one cell of
every kind on a small (2,2,2) pod mesh, and the compressed cross-pod
grad sync must be numerically exact up to int8 quantization."""
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

from repro.launch.inputs import build_cell           # noqa: E402
from repro.launch.roofline import collective_bytes_from_hlo  # noqa: E402


def check_cells():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cells = [("qwen3-0.6b", "train_4k"), ("qwen3-0.6b", "prefill_32k"),
             ("qwen3-0.6b", "decode_32k"), ("xlstm-350m", "decode_32k"),
             ("recurrentgemma-9b", "prefill_32k")]
    for arch, shape in cells:
        cell = build_cell(arch, shape, mesh)
        names = list(cell.kwargs)
        jitted = jax.jit(lambda *a: cell.fn(**dict(zip(names, a))),
                         in_shardings=tuple(cell.in_shardings.get(n)
                                            for n in names),
                         out_shardings=cell.out_shardings)
        with mesh:
            compiled = jitted.lower(
                *[cell.kwargs[n] for n in names]).compile()
        coll = collective_bytes_from_hlo(compiled.as_text())
        assert compiled.cost_analysis() is not None
        print(f"OK cell {arch} x {shape} (multi-pod mini mesh) "
              f"coll={sum(coll.values())}")


def _shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map (0.5+, check_vma) or the experimental module
    (0.4.x, check_rep) — whichever this jax provides."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def check_grad_sync():
    from repro.training.grad_sync import _sync_one
    mesh = jax.make_mesh((4,), ("pod",))
    g = np.random.default_rng(0).normal(size=(4, 32, 16)).astype(np.float32)

    fn = jax.jit(_shard_map(
        lambda x: _sync_one(x[0], "pod")[None],
        mesh=mesh, in_specs=P("pod"), out_specs=P("pod")))
    with mesh:
        out = np.asarray(fn(jnp.asarray(g)))
    want = g.mean(axis=0)
    for i in range(4):
        np.testing.assert_allclose(out[i], want, atol=2e-2)
    # int8 all-gather must appear in the lowered HLO (wire-level claim).
    with mesh:
        txt = jax.jit(_shard_map(
            lambda x: _sync_one(x[0], "pod")[None], mesh=mesh,
            in_specs=P("pod"), out_specs=P("pod"))
            ).lower(jnp.asarray(g)).compile().as_text()
    assert "s8[" in txt and "all-gather" in txt
    print("OK grad_sync int8 wire format + numerics")


if __name__ == "__main__":
    check_cells()
    check_grad_sync()
    print("ALL OK")
