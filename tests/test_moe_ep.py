"""EP-grouped MoE dispatch == ungrouped dispatch (numerical equivalence).

The 2D expert-parallel formulation (§Perf-3) is what the production
train cells lower; it must compute the same function as the plain
dispatch when capacity is no-drop. (With drops the two differ only in
WHICH overflow tokens drop — per-group vs global capacity.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.models.moe import apply_moe


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "kimi-k2-1t-a32b"])
def test_ep_grouped_equals_plain(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    lp = jax.tree.map(lambda a: a[0], params["moe_layers"])["moe"]
    x = jax.random.normal(key, (4, 8, cfg.d_model),
                          jnp.float32).astype(cfg.dtype)
    with _mesh11():
        plain = apply_moe(lp, x, cfg, capacity_factor=-1.0, ep_groups=0)
        grouped = apply_moe(lp, x, cfg, capacity_factor=-1.0, ep_groups=4)
    np.testing.assert_allclose(np.asarray(plain, np.float32),
                               np.asarray(grouped, np.float32),
                               atol=3e-2, rtol=3e-2)


@settings(max_examples=10, deadline=None)
@given(groups=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_ep_grouped_equivalence_property(groups, seed):
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    key = jax.random.PRNGKey(seed)
    params = init_params(jax.random.PRNGKey(0), cfg)
    lp = jax.tree.map(lambda a: a[0], params["moe_layers"])["moe"]
    x = jax.random.normal(key, (groups, 8, cfg.d_model),
                          jnp.float32).astype(cfg.dtype)
    with _mesh11():
        plain = apply_moe(lp, x, cfg, capacity_factor=-1.0, ep_groups=0)
        grouped = apply_moe(lp, x, cfg, capacity_factor=-1.0,
                            ep_groups=groups)
    np.testing.assert_allclose(np.asarray(plain, np.float32),
                               np.asarray(grouped, np.float32),
                               atol=3e-2, rtol=3e-2)
