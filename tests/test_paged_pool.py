"""Paged pool serving path: paged/dist decode equivalence, metadata-only
KV moves, and the bounded-recompilation guarantee."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.prefill as prefill_mod
from repro.configs import get_smoke_config
from repro.kernels.ops import paged_micro_attention
from repro.models.model import decode_step, init_params
from repro.models.prefill import decode_step_dist, decode_step_paged, prefill
from repro.serving import (Cluster, Request, RequestState, SamplingParams,
                           ServingConfig)
from repro.serving.kvpool import (RankKVPool, build_local_tables,
                                  read_pool_rows, table_bucket,
                                  write_pool_rows)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_reference(params, cfg, prompt, n_new):
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
    return out


# ------------------------------------------------------------------ #
# kvpool device helpers
# ------------------------------------------------------------------ #
def test_pool_rows_roundtrip():
    L, NB, bs, K, hd = 2, 6, 4, 2, 8
    pool = jnp.zeros((L, NB, bs, K, hd), jnp.float32)
    rows = jax.random.normal(jax.random.PRNGKey(0), (L, 7, K, hd))
    pool = write_pool_rows(pool, [3, 1], rows, bs)
    got = read_pool_rows(pool, [3, 1], bs)
    np.testing.assert_array_equal(np.asarray(got[:, :7]), np.asarray(rows))
    np.testing.assert_array_equal(np.asarray(got[:, 7:]),
                                  np.zeros((L, 1, K, hd)))


def test_table_bucket_is_coarse():
    assert table_bucket(1) == 8 and table_bucket(8) == 8
    assert table_bucket(9) == 16 and table_bucket(100) == 128
    # Any span length maps onto log2-many buckets.
    assert len({table_bucket(n) for n in range(1, 257)}) <= 6


def test_paged_op_backends_agree():
    key = jax.random.PRNGKey(7)
    R, NB, bs, K, G, D, MB = 3, 12, 8, 2, 2, 16, 4
    H = K * G
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (R, H, D))
    pool_k = jax.random.normal(kk, (NB, bs, K, D))
    pool_v = jax.random.normal(kv, (NB, bs, K, D))
    table = jnp.asarray([[0, 3, 5, -1], [7, -1, -1, -1], [2, 4, 6, 8]],
                        jnp.int32)
    tail = jnp.asarray([5, 8, 2], jnp.int32)
    a = paged_micro_attention(q, pool_k, pool_v, table, tail, backend="jnp")
    b = paged_micro_attention(q, pool_k, pool_v, table, tail,
                              backend="pallas", interpret=True)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------------ #
# decode_step_paged == decode_step_dist (same tokens, same KV)
# ------------------------------------------------------------------ #
def test_decode_step_paged_matches_dist(setup):
    cfg, params = setup
    key = jax.random.PRNGKey(2)
    B, T, bs = 2, 24, 8
    n_over, maxlen = 8, 16                     # dist ring keeps [8, 24)
    n_local = T - n_over
    tokens = jax.random.randint(key, (B, T + 3), 0, cfg.vocab_size)

    _, full_state = prefill(params, cfg, tokens[:, :T], max_len=T + 8)

    # --- dist path (dense spans + ring), as the serving engine ran it.
    _, ring_state = prefill(params, cfg, tokens[:, :T], max_len=maxlen)
    remote_k = full_state.kv_k[:, :, :n_over + 3]
    remote_v = full_state.kv_v[:, :, :n_over + 3]

    # --- paged path: owner pool holds the tail, creditor pool the prefix.
    L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.dtype)
    own_k = jnp.zeros((L, 16, bs, K, hd), dt)
    own_v = jnp.zeros((L, 16, bs, K, hd), dt)
    cred_k = jnp.zeros((L, 16, bs, K, hd), dt)
    cred_v = jnp.zeros((L, 16, bs, K, hd), dt)
    own_pool, cred_pool = RankKVPool(16, bs), RankKVPool(16, bs)
    for b in range(B):
        own_pool.append_tokens(b, n_local)
        blocks = own_pool.requests[b].blocks
        own_k = write_pool_rows(own_k, blocks,
                                full_state.kv_k[:, b, n_over:T], bs)
        own_v = write_pool_rows(own_v, blocks,
                                full_state.kv_v[:, b, n_over:T], bs)
        cred_pool.append_tokens(b, n_over)
        cblocks = cred_pool.requests[b].blocks
        cred_k = write_pool_rows(cred_k, cblocks,
                                 full_state.kv_k[:, b, :n_over], bs)
        cred_v = write_pool_rows(cred_v, cblocks,
                                 full_state.kv_v[:, b, :n_over], bs)

    st = ring_state
    for i, t in enumerate(range(T, T + 3)):
        start_i = T + i + 1 - maxlen
        lg_dist, st = decode_step_dist(
            params, cfg, st, tokens[:, t],
            jnp.full((B,), start_i, jnp.int32), remote_k, remote_v,
            jnp.full((B,), start_i, jnp.int32))

        wblk = np.zeros(B, np.int32)
        woff = np.zeros(B, np.int32)
        for b in range(B):
            own_pool.append_tokens(b, 1)
            rb = own_pool.requests[b]
            wblk[b] = rb.blocks[-1]
            woff[b] = rb.tail_tokens - 1
        needed = max(len(own_pool.requests[b].blocks) for b in range(B))
        tables, tails = build_local_tables([own_pool, cred_pool],
                                           list(range(B)),
                                           table_bucket(needed))
        lg_paged, own_k, own_v = decode_step_paged(
            params, cfg, tokens[:, t], np.full(B, T + i, np.int32),
            own_k, own_v, tables, tails, wblk, woff,
            remote_pools=((cred_k, cred_v),))
        np.testing.assert_allclose(np.asarray(lg_paged, np.float32),
                                   np.asarray(lg_dist, np.float32),
                                   atol=2e-2, rtol=2e-2)


# ------------------------------------------------------------------ #
# A KV move is metadata + pool rows only; logits survive the boundary
# ------------------------------------------------------------------ #
def test_move_is_metadata_only(setup):
    cfg, params = setup
    rng = np.random.default_rng(11)
    prompt = list(rng.integers(0, cfg.vocab_size, size=40))
    n_new = 20
    ref = _greedy_reference(params, cfg, prompt, n_new)

    cl = Cluster(params, cfg, ServingConfig.smoke(
        max_batch=2, pool_blocks=32))
    req = Request(prompt=prompt, sampling=SamplingParams(max_new_tokens=n_new))
    cl.submit(req)

    # Acceptance: no dense-array span/host dicts anywhere in the engines.
    for eng in cl.engines.values():
        assert not hasattr(eng, "remote") and not hasattr(eng, "hosted")
    shapes = {i: (e.pool_k.shape, e.pool_v.shape)
              for i, e in cl.engines.items()}
    total_blocks = {i: e.rmanager.pool.alloc.num_blocks
                    for i, e in cl.engines.items()}

    owner = creditor = None
    moved = False
    for _ in range(200):
        pre_moves = sum(len(e.stats.tokens_moved_steps)
                        for e in cl.engines.values())
        cl.step()
        post_moves = sum(len(e.stats.tokens_moved_steps)
                         for e in cl.engines.values())
        if not moved and post_moves > pre_moves:
            moved = True
            owner = next(e for e in cl.engines.values()
                         if req.req_id in e.remote_insts)
            creditor = cl.engines[owner.remote_insts[req.req_id][-1]]
            # Pool tensors were edited in place-shape: no new allocations.
            for i, e in cl.engines.items():
                assert (e.pool_k.shape, e.pool_v.shape) == shapes[i]
                assert e.rmanager.pool.alloc.num_blocks == total_blocks[i]
            # The creditor's table now addresses the moved blocks.
            assert creditor.rmanager.is_hosting(req.req_id)
            assert creditor.rmanager.pool.requests[req.req_id].blocks
        if req.done:
            break
    assert moved, "scenario never triggered a KV move"
    assert req.state == RequestState.FINISHED
    # Logits (greedy argmax stream) are unchanged across the move boundary.
    assert req.output == ref


# ------------------------------------------------------------------ #
# Recompiles bounded by table buckets / rank counts, not span growth
# ------------------------------------------------------------------ #
def test_recompile_count_bounded_by_buckets(setup):
    cfg, params = setup
    rng = np.random.default_rng(5)
    # Distinctive shapes so this test's traces are not already cached.
    cl = Cluster(params, cfg, ServingConfig.smoke(
        max_batch=2, max_local_len=12, pool_blocks=24, block_size=4,
        move_chunk_tokens=4, prefill_chunk=32))
    req = Request(prompt=list(rng.integers(0, cfg.vocab_size, size=10)),
                  sampling=SamplingParams(max_new_tokens=26))
    before = prefill_mod.paged_trace_count()
    cl.submit(req)
    cl.run_until_done(max_steps=300)
    traces = prefill_mod.paged_trace_count() - before

    assert req.state == RequestState.FINISHED
    n_moves = sum(len(e.stats.tokens_moved_steps)
                  for e in cl.engines.values())
    assert n_moves >= 4, f"wanted >=4 KV moves, got {n_moves}"
    assert 1 <= traces <= 2, \
        f"decode step retraced {traces}x across {n_moves} moves"
