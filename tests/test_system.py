"""End-to-end behaviour tests for the paper's system (top level)."""
import jax
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config, get_smoke_config
from repro.models.model import init_params
from repro.serving import (LLMServer, RequestState, SamplingParams,
                           ServingConfig)


def test_all_archs_registered_with_exact_dims():
    assert len(ARCH_IDS) == 10
    kimi = get_config("kimi-k2-1t-a32b")
    assert (kimi.num_layers, kimi.d_model, kimi.num_experts,
            kimi.top_k) == (61, 7168, 384, 8)
    assert abs(kimi.param_count() / 1e12 - 1.03) < 0.05      # ~1T
    assert abs(kimi.active_param_count() / 1e9 - 33.7) < 2   # ~A32B
    assert len(SHAPES) == 4
    assert SHAPES["long_500k"].seq_len == 524_288


def test_system_end_to_end_mixed_cluster():
    """The paper's headline behaviour, end to end at smoke scale: a
    cluster serves a mix of short requests and one request whose KV
    exceeds any single instance, with exact greedy outputs."""
    cfg = get_smoke_config("qwen3-0.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    server = LLMServer(params, cfg, ServingConfig.smoke(
        n_instances=3, max_batch=2, pool_blocks=32))
    handles = [server.submit(rng.integers(0, cfg.vocab_size, size=n).tolist(),
                             SamplingParams(max_new_tokens=6))
               for n in (5, 50, 9)]
    server.drain(max_steps=300)
    assert all(h.status == RequestState.FINISHED for h in handles)
    assert server.cluster.throughput_stats["kv_moved_bytes"] > 0
