"""End-to-end behaviour tests for the paper's system (top level)."""
import jax
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config, get_smoke_config
from repro.models.model import init_params
from repro.serving import Cluster, Request, RequestState, SamplingParams


def test_all_archs_registered_with_exact_dims():
    assert len(ARCH_IDS) == 10
    kimi = get_config("kimi-k2-1t-a32b")
    assert (kimi.num_layers, kimi.d_model, kimi.num_experts,
            kimi.top_k) == (61, 7168, 384, 8)
    assert abs(kimi.param_count() / 1e12 - 1.03) < 0.05      # ~1T
    assert abs(kimi.active_param_count() / 1e9 - 33.7) < 2   # ~A32B
    assert len(SHAPES) == 4
    assert SHAPES["long_500k"].seq_len == 524_288


def test_system_end_to_end_mixed_cluster():
    """The paper's headline behaviour, end to end at smoke scale: a
    cluster serves a mix of short requests and one request whose KV
    exceeds any single instance, with exact greedy outputs."""
    cfg = get_smoke_config("qwen3-0.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    cl = Cluster(params, cfg, n_instances=3, max_batch=2, max_local_len=32,
                 pool_blocks=32, block_size=8, move_chunk_tokens=8)
    reqs = [Request(prompt=list(rng.integers(0, cfg.vocab_size, size=n)),
                    sampling=SamplingParams(max_new_tokens=6))
            for n in (5, 50, 9)]
    for r in reqs:
        cl.submit(r)
    cl.run_until_done(max_steps=300)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert cl.throughput_stats["kv_moved_bytes"] > 0
