"""Fault-tolerant DistAttention (ISSUE-9): detection, deterministic
token-replay recovery, and chaos injection.

Covers the tentpole's correctness surface:

  (a) crash recovery token identity — killing a CREDITOR rank holding a
      spanning request's hosted KV (or the OWNER itself) re-admits the
      request via token replay (re-prefill of prompt + output[:-1], no
      resampling) and the final greedy output is byte-identical to an
      unfailed oracle, in BOTH per-instance and global-pool modes;
  (b) detection budgets — a heartbeat-silence gap shorter than
      ``FaultPolicy.heartbeat_timeout_steps`` is tolerated (the miss
      counter resets on the next beat); a longer one kills the instance
      and recovery still reproduces the oracle stream;
  (c) a move stripe whose leg fails mid-execution rolls back the
      remaining reservations exactly and re-plans against surviving
      creditors — tokens unaffected, no reserved-block leak;
  (d) AsyncStager/HostKVTier transfer faults: transient errors are
      retried (counted per tag) and absorbed; exhaustion propagates
      with the in-flight ring drained clean instead of swallowed;
  (e) host-frame content-hash verification: a corrupted frame raises
      ``FrameCorruptionError`` and is dropped (real bit-rot and the
      injected chaos kind), and a corrupted CACHED prefix falls back to
      recompute with identical tokens;
  (f) hypothesis property — under arbitrary seeded ``FaultPlan``s the
      allocators never leak or double-free (the refcount guard raises
      on any double free; reservations and request records drain to
      zero).
"""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.model import decode_step, init_params
from repro.models.prefill import prefill
from repro.serving import (Cluster, LLMServer, Request, RequestState,
                           SamplingParams, ServingConfig)
from repro.serving.config import FaultPolicy
from repro.serving.faults import (FaultEvent, FaultPlan,
                                  FrameCorruptionError, TransferError,
                                  backoff_delay_s)
from repro.serving.hosttier import HostKVTier
from repro.serving.staging import AsyncStager

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module", autouse=True)
def _drop_compiled_twins():
    # This module compiles a float32 twin of nearly every serving
    # executable (plus many distinct 3-instance cluster shapes). Free
    # them once the module is done so the process-wide XLA footprint
    # returns to its pre-module level — a full-suite run accumulated
    # enough native compiler state to segfault inside a LATER module's
    # backend_compile without this.
    yield
    jax.clear_caches()


@pytest.fixture(scope="module")
def setup():
    # float32 so the token-identity assertions are robust to the
    # placement-dependent LSE-merge rounding a fault reshuffles (same
    # convention as the prefix-cache identity tests): a replanned move
    # changes which creditor merges which partial, and in bfloat16 that
    # regrouping alone can flip a late argmax.
    cfg = dataclasses.replace(get_smoke_config("olmo-1b"),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_reference(params, cfg, prompt, n_new):
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
    return out


def _assert_allocators_clean(cl):
    """Every allocator (including quarantined ranks) fully drained."""
    for _ in range(2):                  # flush pending hosted releases
        cl.step()
    for i, e in cl.engines.items():
        a = e.rmanager.pool.alloc
        assert a.used_count == 0, \
            f"inst {i} leaked {a.used_count} blocks"
        assert a.reserved == 0, \
            f"inst {i} leaked {a.reserved} reservations"
        assert not e.rmanager.pool.requests, \
            f"inst {i} kept request records"


def _chaos_config(**over):
    base = dict(n_instances=3, max_batch=2, pool_blocks=32,
                heartbeat_timeout=0.0,
                faults=FaultPolicy(max_transfer_retries=2))
    base.update(over)
    return ServingConfig.smoke(**base)


# ------------------------------------------------------------------ #
# (a) crash recovery token identity, both pool modes
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("global_pool", [False, True],
                         ids=["per-instance", "global-pool"])
def test_creditor_crash_recovery_token_identity(setup, global_pool):
    """Kill the CREDITOR holding a spanning request's hosted span
    mid-decode: token replay reproduces the oracle byte-for-byte."""
    cfg, params = setup
    rng = np.random.default_rng(90)
    prompt = list(rng.integers(0, cfg.vocab_size, size=40))
    n_new = 12
    ref = _greedy_reference(params, cfg, prompt, n_new)

    cl = Cluster(params, cfg, _chaos_config(global_pool=global_pool))
    req = Request(prompt=prompt, sampling=SamplingParams(max_new_tokens=n_new))
    cl.submit(req)
    for _ in range(30):
        cl.step()
        if len(req.output) >= 4:
            break
    assert req.state == RequestState.RUNNING and len(req.output) >= 4
    creditors = [i for i, e in cl.engines.items()
                 if e.rmanager.is_hosting(req.req_id)]
    assert creditors, "scenario produced no hosted span"
    cl.kill_instance(creditors[0])
    cl.run_until_done(max_steps=300)

    assert req.state == RequestState.FINISHED
    assert req.prompt == prompt                 # replay never mutates it
    assert req.output == ref                    # byte-identical stream
    assert req.replays == 1
    assert cl.fault_stats.recoveries == 1
    assert cl.fault_stats.replayed_tokens >= 3
    assert creditors[0] in cl._dead
    _assert_allocators_clean(cl)


@pytest.mark.parametrize("global_pool", [False, True],
                         ids=["per-instance", "global-pool"])
def test_chaos_crash_event_owner_recovery(setup, global_pool):
    """An injected ``FaultPlan`` crash of the OWNER fires at its armed
    step; detection + replay reproduce the oracle in both pool modes."""
    cfg, params = setup
    rng = np.random.default_rng(91)
    prompt = list(rng.integers(0, cfg.vocab_size, size=12))
    n_new = 10
    ref = _greedy_reference(params, cfg, prompt, n_new)

    cl = Cluster(params, cfg, _chaos_config(global_pool=global_pool))
    req = Request(prompt=prompt, sampling=SamplingParams(max_new_tokens=n_new))
    cl.submit(req)
    for _ in range(4):
        cl.step()
    owner = next(i for i, e in cl.engines.items() if req in e.running)
    inj = cl.install_faults(FaultPlan(events=(
        FaultEvent(step=cl._step_count + 1, kind="crash", target=owner),)))
    cl.run_until_done(max_steps=300)

    assert [ev.kind for ev in inj.fired] == ["crash"]
    assert cl.fault_stats.injected == 1
    assert owner in cl._dead
    assert req.state == RequestState.FINISHED
    assert req.output == ref
    assert req.replays == 1
    _assert_allocators_clean(cl)


# ------------------------------------------------------------------ #
# (b) heartbeat-silence detection budgets
# ------------------------------------------------------------------ #
def test_short_silence_tolerated(setup):
    """A silence gap SHORTER than heartbeat_timeout_steps never kills:
    the miss counter resets on the next beat."""
    cfg, params = setup
    rng = np.random.default_rng(92)
    prompt = list(rng.integers(0, cfg.vocab_size, size=8))
    cl = Cluster(params, cfg, _chaos_config(
        n_instances=2, heartbeat_timeout=1e9,
        faults=FaultPolicy(heartbeat_timeout_steps=3)))
    req = Request(prompt=prompt, sampling=SamplingParams(max_new_tokens=8))
    cl.submit(req)
    cl.install_faults(FaultPlan(events=(
        FaultEvent(step=2, kind="silence", target=0, duration=2),
        FaultEvent(step=2, kind="silence", target=1, duration=2),)))
    cl.run_until_done(max_steps=100)
    assert not cl._dead
    assert cl.fault_stats.dead_instances == 0
    assert req.state == RequestState.FINISHED
    assert req.replays == 0


def test_long_silence_declared_dead_and_replayed(setup):
    """A silence gap >= heartbeat_timeout_steps kills the owner; the
    request replays and still matches the oracle exactly."""
    cfg, params = setup
    rng = np.random.default_rng(93)
    prompt = list(rng.integers(0, cfg.vocab_size, size=10))
    n_new = 10
    ref = _greedy_reference(params, cfg, prompt, n_new)
    cl = Cluster(params, cfg, _chaos_config(
        heartbeat_timeout=1e9,
        faults=FaultPolicy(heartbeat_timeout_steps=3)))
    req = Request(prompt=prompt, sampling=SamplingParams(max_new_tokens=n_new))
    cl.submit(req)
    for _ in range(3):
        cl.step()
    owner = next(i for i, e in cl.engines.items() if req in e.running)
    cl.install_faults(FaultPlan(events=(
        FaultEvent(step=cl._step_count + 1, kind="silence", target=owner,
                   duration=6),)))
    cl.run_until_done(max_steps=300)
    assert owner in cl._dead
    assert req.state == RequestState.FINISHED
    assert req.output == ref
    assert req.replays == 1
    _assert_allocators_clean(cl)


# ------------------------------------------------------------------ #
# (c) move-leg failure: exact rollback + re-plan on survivors
# ------------------------------------------------------------------ #
def test_move_leg_failure_rolls_back_and_replans(setup):
    """An injected mid-stripe leg failure cancels the remaining legs'
    reservations exactly and re-plans on surviving creditors — the
    token stream is untouched and nothing stays reserved."""
    cfg, params = setup
    rng = np.random.default_rng(94)
    prompt = list(rng.integers(0, cfg.vocab_size, size=40))
    n_new = 24                        # forces reactive mid-decode moves
    ref = _greedy_reference(params, cfg, prompt, n_new)

    cl = Cluster(params, cfg, _chaos_config(move_chunk_tokens=8))
    req = Request(prompt=prompt, sampling=SamplingParams(max_new_tokens=n_new))
    cl.submit(req)
    cl.install_faults(FaultPlan(events=(
        FaultEvent(step=1, kind="move_leg", count=1),)))
    cl.run_until_done(max_steps=300)

    assert cl.fault_stats.move_leg_failures == 1
    assert req.state == RequestState.FINISHED
    assert req.output == ref
    assert req.replays == 0           # a failed move never costs a replay
    _assert_allocators_clean(cl)


# ------------------------------------------------------------------ #
# (d) stager retry / exhaustion / ring drain
# ------------------------------------------------------------------ #
def test_stager_retry_absorbs_transient_fault():
    stager = AsyncStager(overlap=True, depth=2, max_retries=2)
    fires = iter([True])              # exactly one injected timeout
    stager.fault_hook = lambda tag: next(fires, False)
    stager.stage(jnp.zeros(4), tag="spill")
    stager.commit()
    assert stager.retries["spill"] == 1
    assert sum(stager.failures.values()) == 0
    assert not stager._inflight


def test_stager_exhaustion_propagates_and_drains_ring():
    stager = AsyncStager(overlap=True, depth=4, max_retries=1)
    stager.fault_hook = lambda tag: tag == "boom"   # persistent fault
    stager.stage(jnp.ones(4), tag="boom")
    stager.stage(jnp.zeros(4), tag="ok")            # healthy chain behind
    with pytest.raises(TransferError):
        stager.commit()
    assert not stager._inflight       # ring drained clean, not abandoned
    assert stager.retries["boom"] == 1
    assert stager.failures["boom"] == 1
    assert stager.failures.get("ok", 0) == 0


# ------------------------------------------------------------------ #
# (e) host-tier verification + injected fetch faults
# ------------------------------------------------------------------ #
def _tier_with_frame(**kw):
    tier = HostKVTier(4, verify=True, **kw)
    k = np.arange(16, dtype=np.float32).reshape(2, 8)
    tier.put("n", k, -k)
    tier.drain(block=True)
    return tier


def test_host_tier_detects_real_bitrot():
    tier = _tier_with_frame()
    assert tier.get("n") is not None
    k, v = tier._frames["n"]
    bad = k.copy()
    bad[0, 0] += 1.0                  # one flipped value
    tier._frames["n"] = (bad, v)
    with pytest.raises(FrameCorruptionError):
        tier.get("n")
    assert "n" not in tier            # poisoned frame dropped
    assert tier.stats.corruptions == 1


def test_host_tier_injected_corruption_detected():
    tier = _tier_with_frame()
    tier.fault_hook = lambda key: "corrupt"
    with pytest.raises(FrameCorruptionError):
        tier.get("n")
    assert "n" not in tier
    assert tier.stats.corruptions == 1


def test_host_tier_fetch_retry_then_exhaustion():
    tier = _tier_with_frame(max_retries=2)
    modes = iter(["error"])           # one transient fetch error
    tier.fault_hook = lambda key: next(modes, None)
    assert tier.get("n") is not None
    assert tier.stats.fetch_retries == 1
    tier.fault_hook = lambda key: "error"
    with pytest.raises(TransferError):
        tier.get("n")
    assert tier.stats.fetch_failures == 1
    assert "n" in tier                # transient errors never drop data


def test_backoff_delay_doubles_and_caps():
    assert backoff_delay_s(0, 0.0, 1.0) == 0.0
    assert backoff_delay_s(0, 0.01, 0.04) == pytest.approx(0.01)
    assert backoff_delay_s(1, 0.01, 0.04) == pytest.approx(0.02)
    assert backoff_delay_s(5, 0.01, 0.04) == pytest.approx(0.04)


def test_corrupted_cached_prefix_falls_back_to_recompute(setup):
    """Bit-rot a host-resident cached prefix frame: the warm admission
    detects it, recomputes from tokens, and still matches the oracle."""
    cfg, params = setup
    rng = np.random.default_rng(95)
    prompt = rng.integers(0, cfg.vocab_size, 24).tolist()
    n_new = 6
    ref = _greedy_reference(params, cfg, prompt, n_new)
    server = LLMServer(params, cfg, ServingConfig.smoke(
        n_instances=1, max_batch=2, max_local_len=64, pool_blocks=48,
        block_size=8, prefill_chunk=8, prefix_cache=True,
        host_tier_blocks=32))
    cl = server.cluster
    assert server.submit(prompt,
                         SamplingParams(max_new_tokens=n_new)).result() == ref
    assert cl.prefix_cache.evict_device(0, 100) > 0   # all frames -> host
    cl.host_tier.drain(block=True)
    key = next(iter(cl.host_tier._frames))
    k, v = cl.host_tier._frames[key]
    bad = k.copy().reshape(-1)
    bad[0] += 1.0
    cl.host_tier._frames[key] = (bad.reshape(k.shape), v)

    warm = server.submit(prompt, SamplingParams(max_new_tokens=n_new))
    assert warm.result() == ref                      # fallback recompute
    assert cl.host_tier.stats.corruptions >= 1
    assert server.metrics["host_frame_corruptions"] >= 1.0


# ------------------------------------------------------------------ #
# FaultPlan determinism + validation
# ------------------------------------------------------------------ #
def test_fault_plan_from_seed_is_deterministic():
    a = FaultPlan.from_seed(7, n_steps=50, n_instances=4)
    b = FaultPlan.from_seed(7, n_steps=50, n_instances=4)
    c = FaultPlan.from_seed(8, n_steps=50, n_instances=4)
    assert a == b
    assert a != c
    crashes = [e for e in a.events if e.kind == "crash"]
    assert len(crashes) <= 1          # default max_crashes budget
    assert all(1 <= e.step <= 50 and 0 <= e.target < 4
               for e in a.events)


def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(step=1, kind="meteor")
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="crash")
    with pytest.raises(ValueError):
        FaultEvent(step=1, kind="silence", duration=0)


# ------------------------------------------------------------------ #
# (f) hypothesis property: no leak / double-free under seeded plans
# ------------------------------------------------------------------ #
def _run_chaos_workload(params, cfg, seed):
    cl = Cluster(params, cfg, _chaos_config(
        heartbeat_timeout=1e9,
        faults=FaultPolicy(heartbeat_timeout_steps=2,
                           max_transfer_retries=2)))
    rng = np.random.default_rng(seed)
    reqs = []
    for n in (40, 8, 12):             # one spanning + two short
        reqs.append(Request(
            prompt=list(rng.integers(0, cfg.vocab_size, size=n)),
            sampling=SamplingParams(max_new_tokens=6)))
        cl.submit(reqs[-1])
    cl.install_faults(FaultPlan.from_seed(
        seed, n_steps=25, n_instances=len(cl.engines)))
    cl.run_until_done(max_steps=250)
    for r in reqs:                    # FAILED is allowed, stuck is not
        assert r.done, f"request {r.req_id} stuck in {r.state}"
    _assert_allocators_clean(cl)


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_allocators_never_leak_under_seeded_fault_plans(setup, seed):
        """Any seeded FaultPlan: requests terminate, every allocator
        drains to zero, and the double-free guard never fires."""
        cfg, params = setup
        _run_chaos_workload(params, cfg, seed)
else:                                            # pragma: no cover
    @pytest.mark.parametrize("seed", [0, 1, 9, 42])
    def test_allocators_never_leak_under_seeded_fault_plans(setup, seed):
        """Seeded fallback for the hypothesis property (not installed):
        same invariants over a fixed seed sweep."""
        cfg, params = setup
        _run_chaos_workload(params, cfg, seed)
