"""Host-DRAM KV tier + cross-request radix prefix cache (ISSUE-6).

Covers the satellite checklist:
  (a) hypothesis properties of the radix index against a jax-free stub
      cluster: insert/match/evict never over-pin, node refcount always
      equals the number of live request references, pinned replicas are
      never evicted, the tree stays closed under parents, and every
      frame returns to the allocator when the cache lets go;
  (b) the copy-on-write tail of a full-prompt hit never aliases a
      shared frame — shared bytes are unchanged after the warm request
      decodes;
  (c) token identity: cached-prefix admission (cold, warm-full,
      warm-partial, host-prefetched) matches the dense oracle exactly
      (float32 so paged-vs-dense rounding cannot flip argmax);
  (d) the PR-5 exact-rollback guarantee extended to the new tiers:
      cancel mid-streaming-prefill with pinned cache blocks restores
      every allocator EXACTLY, unpins exactly once (the allocator's
      double-free guard would raise otherwise) and leaves the host
      tier untouched;
  (e) Algorithm-1 plumbing: ``Heartbeat.cache_blocks`` reaches the
      scheduler views, widens creditor capacity, and placements that
      displace cached frames are charged the spill penalty.
"""
import collections
import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest

from repro.serving.hosttier import HostKVTier
from repro.serving.kvpool import BlockAllocator
from repro.serving.prefixcache import CACHE_OWNER, RadixPrefixCache

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False

BS = 4          # stub block size
POOL = 24       # stub pool blocks per instance


# ------------------------------------------------------------------ #
# jax-free stub cluster for the index properties
# ------------------------------------------------------------------ #
class _StubEngine:
    def __init__(self, num_blocks, bs):
        self.rmanager = SimpleNamespace(
            pool=SimpleNamespace(alloc=BlockAllocator(num_blocks, bs)))
        self.stats = SimpleNamespace(kv_moved=0, host_spill_bytes=0,
                                     host_prefetch_bytes=0)
        self.frames = {}                 # blk -> (k, v) np rows
        self.pool_k = self.pool_v = None

    def read_block_rows(self, blk):
        return self.frames.get(
            blk, (np.zeros((2, BS), np.float32),
                  np.zeros((2, BS), np.float32)))

    def write_block_rows(self, blk, k, v):
        self.frames[blk] = (np.array(k, copy=True), np.array(v, copy=True))


class _StubCluster:
    def __init__(self, n_inst=2, num_blocks=POOL, bs=BS, tier_blocks=0):
        self.block_size = bs
        self.engines = {i: _StubEngine(num_blocks, bs)
                        for i in range(n_inst)}
        self.stager = SimpleNamespace(stage=lambda arrays, tag=None: None)
        self._dead = set()


def _mk(n_inst=2, tier_blocks=8):
    cl = _StubCluster(n_inst=n_inst)
    tier = HostKVTier(tier_blocks) if tier_blocks else None
    return cl, RadixPrefixCache(cl, host_tier=tier)


# Chunk alphabet: few distinct blocks => chains share prefixes often.
_CHUNKS = [(t,) * BS for t in range(4)]


def _chain_tokens(path):
    return [tok for chunk in path for tok in chunk]


def _simulate_finished_request(cl, cache, inst, path, rid):
    """A finished request's chain: alloc frames, fill KV rows, insert
    into the cache, release the request's own references."""
    alloc = cl.engines[inst].rmanager.pool.alloc
    blocks = alloc.alloc(len(path), rid)
    if blocks is None:
        cache.evict_device(inst, len(path))
        blocks = alloc.alloc(len(path), rid)
        if blocks is None:
            return False
    for blk, chunk in zip(blocks, path):
        row = np.full((2, BS), float(hash(chunk) % 997), np.float32)
        cl.engines[inst].frames[blk] = (row, -row)
    cache.insert_chain(inst, _chain_tokens(path), blocks)
    alloc.free(blocks)
    return True


def _check_invariants(cl, cache):
    # refcount == live request references, never negative.
    refs = collections.Counter()
    for pinned in cache._pins.values():
        for nd in pinned:
            refs[id(nd)] += 1
    for nd in cache._nodes.values():
        assert nd.refcount == refs[id(nd)], \
            f"refcount {nd.refcount} != live refs {refs[id(nd)]}"
        # No storage-less zombies: a node lives on a device or the host.
        assert nd.replicas or nd.on_host
        # Tree closed under parents; child link is consistent.
        assert nd.parent is cache.root or \
            nd.parent.hash in cache._nodes
        assert nd.parent.children.get(nd.tokens) is nd
    # Device replicas are live allocator frames, one reference held by
    # the cache (plus any sharing requests).
    for i, eng in cl.engines.items():
        alloc = eng.rmanager.pool.alloc
        seen = set()
        for nd in cache._nodes.values():
            blk = nd.replicas.get(i)
            if blk is None:
                continue
            assert blk not in seen, "two nodes share one frame"
            seen.add(blk)
            assert alloc.refcount(blk) >= 1
        assert len(seen) == cache.device_blocks(i)
    # Host tier occupancy is bounded and every on_host node is present.
    if cache.tier is not None:
        assert cache.tier.used_blocks <= cache.tier.capacity
        for nd in cache._nodes.values():
            if nd.on_host:
                assert nd.hash in cache.tier


def _exercise_radix(pick, tier_blocks, n_ops):
    """Shared driver for the radix-index property: ``pick`` is any
    ``(sample_from_list, randint)`` pair — hypothesis draws or a seeded
    PRNG — choosing the interleaving of ops."""
    sample, randint = pick
    cl, cache = _mk(n_inst=2, tier_blocks=tier_blocks)
    live = {}
    next_rid = [0]

    def draw_path():
        return [sample(_CHUNKS) for _ in range(randint(1, 4))]

    for _ in range(n_ops):
        op = sample(["insert", "acquire", "release", "evict", "drain"])
        inst = sample(sorted(cl.engines))
        if op == "insert":
            next_rid[0] += 1
            _simulate_finished_request(cl, cache, inst, draw_path(),
                                       next_rid[0])
        elif op == "acquire":
            next_rid[0] += 1
            rid = next_rid[0]
            got = cache.acquire(inst, rid, _chain_tokens(draw_path()),
                                max_blocks=randint(0, 5))
            live[rid] = got
            # Matched blocks are pinned: evicting CANNOT free them.
            pinned_before = cache.pinned_blocks(inst)
            cache.evict_device(inst, POOL)
            assert cache.pinned_blocks(inst) == pinned_before
            assert all(nd.replicas.get(inst) is not None
                       for nd in cache._pins.get(rid, []))
        elif op == "release" and live:
            rid = sample(sorted(live))
            cache.release(rid)
            del live[rid]
        elif op == "evict":
            cache.evict_device(inst, randint(1, POOL))
        elif op == "drain" and cache.tier is not None:
            cache.tier.drain(block=True)
        _check_invariants(cl, cache)
    # Teardown: release every pin, evict everything -> zero leaks.
    for rid in list(live):
        cache.release(rid)
    for i in cl.engines:
        cache.evict_device(i, POOL)
    _check_invariants(cl, cache)
    for i, eng in cl.engines.items():
        assert cache.device_blocks(i) == 0
        assert eng.rmanager.pool.alloc.used_count == 0, \
            "cache leaked device frames"


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.data())
    def test_radix_index_properties(data):
        """Random insert/acquire/release/evict interleavings keep every
        index invariant, and releasing everything leaks zero frames."""
        pick = (lambda xs: data.draw(st.sampled_from(list(xs))),
                lambda a, b: data.draw(st.integers(a, b)))
        _exercise_radix(pick, tier_blocks=data.draw(
            st.sampled_from([0, 6])), n_ops=data.draw(st.integers(5, 25)))


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("tier_blocks", [0, 6])
def test_radix_index_properties_seeded(seed, tier_blocks):
    """Deterministic twin of the hypothesis property so the invariants
    run even where hypothesis is not installed."""
    import random
    rng = random.Random(1000 * tier_blocks + seed)
    pick = (lambda xs: rng.choice(list(xs)), rng.randint)
    _exercise_radix(pick, tier_blocks=tier_blocks, n_ops=25)


def test_release_is_exactly_once_and_idempotent():
    cl, cache = _mk(n_inst=1, tier_blocks=0)
    _simulate_finished_request(cl, cache, 0, _CHUNKS[:3], rid=1)
    got = cache.acquire(0, 2, _chain_tokens(_CHUNKS[:3]), max_blocks=3)
    assert len(got) == 3
    assert cache.pinned_blocks(0) == 3
    cache.release(2)
    assert cache.pinned_blocks(0) == 0
    cache.release(2)                     # second release: no-op
    assert all(nd.refcount == 0 for nd in cache._nodes.values())


def test_double_acquire_without_release_asserts():
    cl, cache = _mk(n_inst=1, tier_blocks=0)
    _simulate_finished_request(cl, cache, 0, _CHUNKS[:2], rid=1)
    toks = _chain_tokens(_CHUNKS[:2])
    assert cache.acquire(0, 7, toks, max_blocks=2)
    with pytest.raises(AssertionError):
        cache.acquire(0, 7, toks, max_blocks=2)


def test_host_spill_and_prefetch_round_trip_content():
    """Evicted replicas land on the host tier byte-exact and come back
    byte-exact into a FRESH frame on re-acquire."""
    cl, cache = _mk(n_inst=1, tier_blocks=8)
    eng = cl.engines[0]
    _simulate_finished_request(cl, cache, 0, _CHUNKS[:2], rid=1)
    orig = {nd.hash: eng.read_block_rows(nd.replicas[0])
            for nd in cache._nodes.values()}
    assert cache.evict_device(0, 2) == 2
    assert cache.device_blocks(0) == 0
    assert cache.host_blocks() == 2
    assert eng.rmanager.pool.alloc.used_count == 0
    got = cache.acquire(0, 2, _chain_tokens(_CHUNKS[:2]), max_blocks=2)
    assert len(got) == 2
    for nd in cache._pins[2]:
        k, v = eng.read_block_rows(nd.replicas[0])
        ok, ov = orig[nd.hash]
        np.testing.assert_array_equal(k, ok)
        np.testing.assert_array_equal(v, ov)
    cache.release(2)


def test_host_lru_eviction_drops_unreachable_subtree():
    """A host-tier watermark eviction of a node with no device replica
    drops its subtree — no orphan child can ever be matched again."""
    cl, cache = _mk(n_inst=1, tier_blocks=3)
    cache.tier.high = cache.tier.low = 1.0   # evict only when full
    for j, path in enumerate(([_CHUNKS[0], _CHUNKS[1]],
                              [_CHUNKS[2]], [_CHUNKS[3]])):
        _simulate_finished_request(cl, cache, 0, path, rid=j + 1)
    # Spill everything to host, oldest first. The 4th spill trips the
    # watermark and LRU-evicts the oldest chain's ROOT; dropping that
    # subtree takes its (already-spilled) child's host frame with it,
    # so no orphan child is ever left matchable.
    assert cache.evict_device(0, POOL) >= 4
    assert cache.host_blocks() == 2
    assert len(cache._nodes) == 2
    for nd in cache._nodes.values():
        assert nd.on_host and not nd.replicas
    assert cache.acquire(0, 99, _chain_tokens(_CHUNKS[:2]),
                         max_blocks=2) == []
    cache.release(99)
    _check_invariants(cl, cache)


# ------------------------------------------------------------------ #
# Engine-level: COW aliasing, token identity, exact rollback
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def served():
    jax = pytest.importorskip("jax")
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    cfg = dataclasses.replace(get_smoke_config("olmo-1b"),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _cache_server(params, cfg, **over):
    from repro.serving import LLMServer, ServingConfig
    base = dict(n_instances=1, max_batch=2, max_local_len=64,
                pool_blocks=48, block_size=8, prefill_chunk=8,
                prefix_cache=True, host_tier_blocks=64)
    base.update(over)
    return LLMServer(params, cfg, ServingConfig.smoke(**base))


def _oracle(params, cfg, prompt, n_new):
    import jax.numpy as jnp
    from repro.models.model import decode_step
    from repro.models.prefill import prefill
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
    return out


def test_cached_prefix_token_identity_vs_oracle(served):
    """Cold, warm-full-hit (COW) and warm-partial-hit admissions all
    produce the oracle's exact token stream."""
    from repro.serving import SamplingParams
    cfg, params = served
    rng = np.random.default_rng(60)
    server = _cache_server(params, cfg)
    full = rng.integers(0, cfg.vocab_size, 24).tolist()    # 3 blocks
    partial = full[:16] + rng.integers(0, cfg.vocab_size, 6).tolist()
    want_full = _oracle(params, cfg, full, 6)
    want_partial = _oracle(params, cfg, partial, 6)
    sp = SamplingParams(max_new_tokens=6)
    assert server.submit(full, sp).result() == want_full       # cold
    assert server.submit(full, sp).result() == want_full       # warm full
    assert server.submit(partial, sp).result() == want_partial  # partial
    assert server.metrics["cache_hit_tokens"] == 23 + 16


def test_host_prefetch_token_identity(served):
    """A chain that round-tripped through the host tier decodes the
    oracle's exact tokens."""
    from repro.serving import SamplingParams
    cfg, params = served
    rng = np.random.default_rng(61)
    server = _cache_server(params, cfg, pool_blocks=9, max_batch=1)
    sp = SamplingParams(max_new_tokens=4)
    prompts = [rng.integers(0, cfg.vocab_size, 24).tolist()
               for _ in range(3)]
    for p in prompts:
        assert server.submit(p, sp).result() == _oracle(params, cfg, p, 4)
    assert server.metrics["host_spill_bytes"] > 0
    for p in prompts:
        assert server.submit(p, sp).result() == _oracle(params, cfg, p, 4)
    assert server.metrics["host_prefetch_bytes"] > 0


def test_cow_tail_never_aliases_shared_frame(served):
    """Mid-decode, a warm full-hit's tail block is request-private and
    the shared frames' bytes never change."""
    from repro.serving import SamplingParams
    cfg, params = served
    rng = np.random.default_rng(62)
    server = _cache_server(params, cfg)
    cl = server.cluster
    eng = cl.engines[0]
    prompt = rng.integers(0, cfg.vocab_size, 24).tolist()
    server.submit(prompt, SamplingParams(max_new_tokens=4)).result()
    cache = cl.prefix_cache
    node_blocks = {nd.hash: nd.replicas[0]
                   for nd in cache._nodes.values()}
    assert len(node_blocks) == 3
    baseline = {h: tuple(np.asarray(a).copy()
                         for a in eng.read_block_rows(b))
                for h, b in node_blocks.items()}
    h = server.submit(prompt, SamplingParams(max_new_tokens=6))
    stepped = 0
    while not h._req.output and stepped < 50:     # drive past admission
        server.step()
        stepped += 1
    rid = h.req_id
    rb = eng.rmanager.pool.requests[rid]
    shared_frames = set(node_blocks.values())
    # Leading blocks ARE the shared frames (table-edit admission)...
    assert set(rb.blocks[:2]) <= shared_frames
    # ...but the COW tail and decode appends are private frames.
    assert not set(rb.blocks[2:]) & shared_frames
    h.result()
    for hsh, b in node_blocks.items():
        for got, want in zip(eng.read_block_rows(b), baseline[hsh]):
            np.testing.assert_array_equal(np.asarray(got), want)


def test_cancel_mid_prefill_with_cache_pins_rolls_back_exactly(served,
                                                               monkeypatch):
    """PR-5 free-spy test extended to the new tiers: a cancel during a
    streaming admission that holds cache pins AND creditor reservations
    restores every allocator exactly, releases each frame at most once,
    unpins every radix node, and leaves the host tier untouched."""
    import repro.serving.cluster as cluster_mod
    from repro.serving import LLMServer, SamplingParams, ServingConfig
    cfg, params = served
    rng = np.random.default_rng(63)
    server = LLMServer(params, cfg, ServingConfig.smoke(
        n_instances=2, max_batch=2, max_local_len=16, pool_blocks=32,
        block_size=4, prefix_cache=True, host_tier_blocks=32))
    cl = server.cluster
    cache = cl.prefix_cache
    sp = SamplingParams(max_new_tokens=4)
    # Warm the cache with the shared prefix: 8 tokens = 2 full blocks,
    # small enough to stay LOCAL (spanning requests skip cache insert).
    prefix = rng.integers(0, cfg.vocab_size, 8).tolist()
    server.submit(prefix, sp).result()
    assert sum(cache.device_blocks(i) for i in cl.engines) >= 2

    def snap():
        """Allocator state, with cache-owned frames factored out: the
        cancelled admission may legitimately GROW the cache (acquire
        materializes D2D replicas on the admitting instance and those
        persist — they are cache state, not request state). Exactness
        means: zero non-cache frames outstanding beyond the request
        table, and every used frame accounted for."""
        out = {}
        for i, e in cl.engines.items():
            a = e.rmanager.pool.alloc
            cache_blks = {nd.replicas[i] for nd in cache._nodes.values()
                          if i in nd.replicas}
            used = set(range(a.num_blocks)) - set(a._free)
            req_blks = {b for rb in e.rmanager.pool.requests.values()
                        for b in rb.blocks}
            assert used >= cache_blks | req_blks
            leaked = used - cache_blks - req_blks
            out[i] = (len(leaked), a.reserved,
                      {r: list(rb.blocks)
                       for r, rb in e.rmanager.pool.requests.items()})
        return out

    before = snap()
    tier_before = (cl.host_tier.used_blocks, cl.host_tier.stats.spills)
    frees = collections.Counter()
    orig_free = BlockAllocator.free

    def spy_free(self, blocks):
        for b in blocks:
            frees[(id(self), b)] += 1
        orig_free(self, blocks)

    monkeypatch.setattr(BlockAllocator, "free", spy_free)
    orig_write = cluster_mod.PrefixSink.write

    def write_then_cancel(self, *a, **kw):
        orig_write(self, *a, **kw)
        server.cancel(self._req_id)

    monkeypatch.setattr(cluster_mod.PrefixSink, "write",
                        write_then_cancel)
    # 40-token prompt reusing the cached prefix: pins both nodes,
    # commits creditor spans, then cancels at the first creditor write.
    prompt = prefix + rng.integers(0, cfg.vocab_size, 32).tolist()
    h = server.submit(prompt, sp)
    for _ in range(30):
        if h.done:
            break
        server.step()
    assert h.status.name == "CANCELLED"
    assert snap() == before, "rollback was not exact"
    assert not cache._pins, "cache pins survived the cancel"
    assert all(nd.refcount == 0 for nd in cache._nodes.values())
    assert (cl.host_tier.used_blocks,
            cl.host_tier.stats.spills) == tier_before
    # No frame was freed more than once per release path (the shared
    # frames must survive: the cache still references them).
    assert all(n == 1 for n in frees.values()), frees
    cached = {blk for nd in cache._nodes.values()
              for blk in nd.replicas.values()}
    assert cached, "cache lost its frames in the rollback"
    # Cluster still serves warm hits after the rollback.
    hits0 = server.metrics["cache_hit_tokens"]
    server.submit(prefix, sp).result()
    assert server.metrics["cache_hit_tokens"] > hits0


# ------------------------------------------------------------------ #
# Algorithm-1 plumbing: cache_blocks as penalized creditor capacity
# ------------------------------------------------------------------ #
def _sched():
    from repro.configs import get_smoke_config
    from repro.serving.perfmodel import InstancePerfModel
    from repro.serving.scheduler import GreedyScheduler
    perf = InstancePerfModel(get_smoke_config("olmo-1b"))
    return GreedyScheduler(perf, block_size=8)


def test_creditor_cap_counts_cache_blocks():
    from repro.serving.scheduler import InstanceView
    s = _sched()
    v = InstanceView(inst_id=0, batch_size=2, mem_blocks_total=32,
                     mem_blocks_used=30, cache_blocks=10)
    assert s._creditor_cap(v) == 2 - 2 + 10
    assert s._creditor_cap(v, with_cache=False) == 0


def test_striped_gain_charges_spill_penalty():
    """Same total capacity, but capacity made of evictable cache frames
    must be charged the host-link spill cost: the modeled gain is
    strictly smaller than for plain free memory."""
    from repro.serving.scheduler import InstanceView
    s = _sched()

    def debtor():
        return InstanceView(
            inst_id=0, batch_size=1, mem_blocks_total=32,
            mem_blocks_used=30,
            requests={7: (30 * 8, 30, True)})

    # Identical creditors (same batch, same request) except that one's
    # headroom is plain free memory and the other's is cache frames.
    free_c = InstanceView(inst_id=1, batch_size=1, mem_blocks_total=32,
                          mem_blocks_used=2, cache_blocks=0,
                          requests={1: (16, 2, True)})
    cache_c = InstanceView(inst_id=1, batch_size=1, mem_blocks_total=32,
                           mem_blocks_used=30, cache_blocks=28,
                           requests={1: (16, 2, True)})
    splits = [(0, 8)]
    g_free = s._striped_gain(debtor(), [free_c], 7, splits)
    g_cache = s._striped_gain(debtor(), [cache_c], 7, splits)
    assert g_cache < g_free


def test_heartbeat_cache_blocks_reaches_views():
    from repro.configs import get_smoke_config
    from repro.serving.gmanager import GManager
    from repro.serving.perfmodel import InstancePerfModel
    from repro.serving.protocol import Heartbeat
    gm = GManager(InstancePerfModel(get_smoke_config("olmo-1b")),
                  block_size=8)
    gm.on_heartbeat(Heartbeat(inst_id=0, seq=1, full=True, entries=[],
                              batch_size=1, mem_blocks_total=32,
                              mem_blocks_used=20, cache_blocks=12),
                    now=0.0)
    (view,) = gm._views()
    assert view.cache_blocks == 12
