"""Request-lifecycle frontend (ISSUE-5): cancellation propagation
through every layer, sampling extensions, and lifecycle-fed scheduling.

Covers the satellite checklist:
  (a) cancel mid-streaming-prefill — the chunk loop aborts between
      chunks, PrefixSink creditor reservations are rolled back via the
      all-or-nothing machinery, and every pool allocator is restored
      EXACTLY to its pre-admission state;
  (b) cancel a request with creditor-hosted spans — spans are released
      exactly once (the allocator's double-free guard would raise);
  (c) cancel racing a planned striped move — the plan resolves
      ``MoveResult.GONE`` before any reservation, no orphans;
  (d) ``SamplingParams.stop_tokens``/``top_k`` against the dense
      oracle (donated-key discipline is asserted in test_zero_copy);
  (e) priority/deadline urgency feeds Algorithm-1's offload ordering.
"""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.serving.cluster as cluster_mod
from repro.configs import get_config, get_smoke_config
from repro.models.model import decode_step, init_params
from repro.models.prefill import prefill
from repro.serving import (InstanceEngine, InstancePerfModel, LLMServer,
                           Request, RequestState, SamplingParams,
                           ServingConfig)
from repro.serving.kvpool import BlockAllocator
from repro.serving.protocol import MoveKVCache, MoveLeg, MoveResult
from repro.serving.scheduler import GreedyScheduler, InstanceView


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_reference(params, cfg, prompt, n_new):
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
    return out


def _alloc_snapshot(cluster):
    out = {}
    for i, e in cluster.engines.items():
        a = e.rmanager.pool.alloc
        out[i] = (a.used_count, a.reserved, sorted(a._free),
                  {r: list(rb.blocks)
                   for r, rb in e.rmanager.pool.requests.items()})
    return out


# ------------------------------------------------------------------ #
# (a) Cancel mid-streaming-prefill: exact allocator rollback
# ------------------------------------------------------------------ #
def test_cancel_mid_streaming_prefill_rolls_back_exactly(setup,
                                                         monkeypatch):
    cfg, params = setup
    rng = np.random.default_rng(30)
    # 40-token prompt, 16-token quota: admission commits a 28-token
    # (7-block) prefix on the creditor BEFORE compute, then streams
    # 8-token chunks through PrefixSink.write.
    server = LLMServer(params, cfg, ServingConfig.smoke(
        max_batch=2, max_local_len=16, pool_blocks=32, block_size=4))
    cl = server.cluster
    before = _alloc_snapshot(cl)

    writes = []
    orig_write = cluster_mod.PrefixSink.write

    def write_then_cancel(self, t0, k, v):
        orig_write(self, t0, k, v)
        writes.append(t0)
        # Cancel lands while the streaming prefill is IN FLIGHT: the
        # admission must abort at the next chunk boundary.
        server.cancel(self._req_id)

    monkeypatch.setattr(cluster_mod.PrefixSink, "write",
                        write_then_cancel)
    h = server.submit(rng.integers(0, cfg.vocab_size, 40).tolist(),
                      SamplingParams(max_new_tokens=8))
    server.step()
    assert writes, "scenario never streamed a creditor chunk"
    assert len(writes) < 4, "admission ran to completion despite cancel"
    assert h.status == RequestState.CANCELLED
    # Creditor reservations AND the owner's local tail blocks are gone;
    # allocator state (counts, free lists, request maps) is EXACTLY the
    # pre-admission state.
    assert _alloc_snapshot(cl) == before
    # The cluster keeps serving: a fresh request admits and finishes.
    h2 = server.submit(rng.integers(0, cfg.vocab_size, 10).tolist(),
                       SamplingParams(max_new_tokens=4))
    assert h2.result() and h2.status == RequestState.FINISHED


# ------------------------------------------------------------------ #
# (b) Cancel with hosted spans: released exactly once
# ------------------------------------------------------------------ #
def test_cancel_with_hosted_spans_releases_once(setup, monkeypatch):
    cfg, params = setup
    rng = np.random.default_rng(31)
    server = LLMServer(params, cfg, ServingConfig.smoke(
        max_batch=2, max_local_len=16, pool_blocks=32, block_size=4))
    cl = server.cluster
    before = _alloc_snapshot(cl)
    h = server.submit(rng.integers(0, cfg.vocab_size, 40).tolist(),
                      SamplingParams(max_new_tokens=32))
    for tok in h.tokens():
        if len(h._req.output) >= 3:
            break
    creditors = [e for e in cl.engines.values()
                 if e.rmanager.is_hosting(h.req_id)]
    assert creditors, "scenario produced no hosted span"
    span_blocks = {(e.inst_id, b) for e in creditors
                   for b in e.rmanager.pool.requests[h.req_id].blocks}

    frees = collections.Counter()
    orig_free = BlockAllocator.free

    def spy_free(self, blocks):
        for b in blocks:
            frees[(id(self), b)] += 1
        orig_free(self, blocks)

    monkeypatch.setattr(BlockAllocator, "free", spy_free)
    alloc_ids = {e.inst_id: id(e.rmanager.pool.alloc)
                 for e in cl.engines.values()}
    assert h.cancel()
    # Drain paths (finished events, schedule rounds) must not re-free.
    for _ in range(4):
        server.step()
    assert h.status == RequestState.CANCELLED
    for inst, b in span_blocks:
        assert frees[(alloc_ids[inst], b)] == 1, \
            f"hosted block {b} on inst {inst} freed " \
            f"{frees[(alloc_ids[inst], b)]}x"
    assert not any(e.rmanager.is_hosting(h.req_id)
                   for e in cl.engines.values())
    assert _alloc_snapshot(cl) == before


# ------------------------------------------------------------------ #
# (c) Cancel racing a planned striped move: GONE, no orphans
# ------------------------------------------------------------------ #
def test_cancel_racing_planned_move_resolves_gone(setup):
    cfg, params = setup
    rng = np.random.default_rng(32)
    server = LLMServer(params, cfg, ServingConfig.smoke(
        n_instances=3, max_batch=2, max_local_len=64, pool_blocks=16,
        block_size=4, schedule_every=10 ** 9))
    cl = server.cluster
    h = server.submit(rng.integers(0, cfg.vocab_size, 40).tolist(),
                      SamplingParams(max_new_tokens=8))
    server.step()
    owner_id = next(i for i, e in cl.engines.items()
                    if h._req in e.running)
    others = [i for i in cl.engines if i != owner_id]
    # A striped plan exists (as if emitted by the gManager)...
    plan = MoveKVCache(h.req_id, owner_id,
                       [MoveLeg(others[0], 2), MoveLeg(others[1], 2)])
    # ...but the request is cancelled before the runtime executes it.
    assert h.cancel()
    snap = _alloc_snapshot(cl)
    assert cl._execute_move(plan) == MoveResult.GONE
    assert _alloc_snapshot(cl) == snap, \
        "GONE plan touched allocator state"
    assert all(e.rmanager.pool.alloc.reserved == 0
               for e in cl.engines.values())


# ------------------------------------------------------------------ #
# (d) SamplingParams extensions vs the dense oracle
# ------------------------------------------------------------------ #
def test_top_k_one_matches_greedy_oracle(setup):
    """top_k=1 collapses stochastic sampling onto the argmax: the
    stream must equal the greedy dense-oracle reference exactly."""
    cfg, params = setup
    rng = np.random.default_rng(33)
    prompt = list(rng.integers(0, cfg.vocab_size, 9))
    n_new = 8
    ref = _greedy_reference(params, cfg, prompt, n_new)
    eng = InstanceEngine(params, cfg, max_batch=2, max_local_len=64,
                         pool_blocks=32, block_size=8, prefill_chunk=8)
    req = Request(prompt=prompt, sampling=SamplingParams(
        max_new_tokens=n_new, temperature=0.9, top_k=1))
    eng.submit(req)
    for _ in range(30):
        if req.done:
            break
        eng.step()
    assert req.state == RequestState.FINISHED
    assert req.output == ref, "top_k=1 sampling diverged from argmax"


def test_top_k_filter_stays_in_top_set():
    """With top_k=3 every sampled token is one of the 3 highest-logit
    tokens of the matching oracle step (float32 so paged-vs-dense
    rounding cannot reorder near-tied logits)."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("olmo-1b"),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(34)
    prompt = list(rng.integers(0, cfg.vocab_size, 7))
    n_new = 6
    eng = InstanceEngine(params, cfg, max_batch=2, max_local_len=64,
                         pool_blocks=32, block_size=8, prefill_chunk=8)
    req = Request(prompt=prompt, sampling=SamplingParams(
        max_new_tokens=n_new, temperature=1.5, top_k=3))
    eng.submit(req)
    for _ in range(30):
        if req.done:
            break
        eng.step()
    assert req.state == RequestState.FINISHED
    # Re-derive each step's top-3 with the dense reference, following
    # the engine's own sampled prefix.
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    for i, tok in enumerate(req.output):
        top3 = set(np.argsort(np.asarray(logits[0]))[-3:].tolist())
        assert tok in top3, f"step {i}: {tok} outside top-3 {top3}"
        logits, state = decode_step(params, cfg, state,
                                    jnp.asarray([tok], jnp.int32))


def test_stop_tokens_terminate_generation(setup):
    cfg, params = setup
    rng = np.random.default_rng(35)
    prompt = list(rng.integers(0, cfg.vocab_size, 11))
    ref = _greedy_reference(params, cfg, prompt, 8)
    stop = ref[2]
    eng = InstanceEngine(params, cfg, max_batch=2, max_local_len=64,
                         pool_blocks=32, block_size=8, prefill_chunk=8)
    req = Request(prompt=prompt, sampling=SamplingParams(
        max_new_tokens=8, stop_tokens=(stop,)))
    eng.submit(req)
    for _ in range(30):
        if req.done:
            break
        eng.step()
    assert req.state == RequestState.FINISHED
    assert req.output == ref[:3], \
        "generation did not stop at the stop token"


# ------------------------------------------------------------------ #
# (e) Priority/deadline urgency orders Algorithm-1 offloads
# ------------------------------------------------------------------ #
def test_urgent_request_offloaded_first():
    cfg = get_config("olmo-1b")
    bs = 512
    sched = GreedyScheduler(InstancePerfModel(cfg), block_size=bs,
                            beta_thres=8, mem_util_thres=0.5)
    debtor = InstanceView(inst_id=0, batch_size=2, mem_blocks_total=110,
                          mem_blocks_used=105,
                          requests={7: (bs * 60, 60, True),
                                    8: (bs * 45, 45, True)})
    creditor = InstanceView(inst_id=1, batch_size=16,
                            mem_blocks_total=100, mem_blocks_used=10,
                            requests={9: (bs * 10, 10, True)})
    # Without lifecycle metadata the longest request (7) is picked.
    base = sched.plan([debtor, creditor])
    assert base and base[0].req_id == 7
    # A near-deadline short request outranks it.
    urgent = sched.plan([debtor, creditor], urgency={8: 100.0})
    assert urgent and urgent[0].req_id == 8, \
        "deadline urgency did not reorder the offload pick"
