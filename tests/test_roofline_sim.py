"""Roofline HLO parsing, trace generator fidelity, cluster simulator."""

from benchmarks.traces import TRACE_SPECS, gen_trace, trace_stats
from repro.configs import get_config
from repro.launch.roofline import (_shape_bytes, collective_bytes_from_hlo,
                                   model_mandatory_bytes,
                                   model_useful_flops)
from repro.configs.base import SHAPES
from repro.serving.simulator import SimRequest, make_policy_cluster


def test_shape_bytes_parsing():
    assert _shape_bytes("f32[32]{0}") == 128
    assert _shape_bytes("bf16[4,8]{1,0}") == 64
    assert _shape_bytes("(f32[2,2]{1,0}, s8[16]{0})") == 32
    assert _shape_bytes("pred[]") == 1


def test_collective_parser_trip_counts():
    hlo = """
HloModule test, num_partitions=8

%body (p: (s32[], f32[4]{0})) -> (s32[], f32[4]{0}) {
  %ar = f32[4]{0} all-reduce(%x), channel_id=1
  ROOT %t = (s32[], f32[4]{0}) tuple(%i, %ar)
}

%cond (p.1: (s32[], f32[4]{0})) -> pred[] {
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i2, %c), direction=LT
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %ag = f32[32]{0} all-gather(%a), channel_id=2
  %w = (s32[], f32[4]{0}) while(%init), condition=%cond, body=%body
  ROOT %g = f32[4]{0} get-tuple-element(%w), index=1
}
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["all-gather"] == 32 * 4
    assert got["all-reduce"] == 4 * 4 * 12       # x trip count


def test_model_flops_and_bytes_positive():
    for arch in ("olmo-1b", "kimi-k2-1t-a32b", "xlstm-350m"):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            assert model_useful_flops(cfg, shape) > 0
            assert model_mandatory_bytes(cfg, shape) > 0
    # MoE useful flops must track ACTIVE params, not total.
    kimi = get_config("kimi-k2-1t-a32b")
    dense_equiv = model_useful_flops(kimi, SHAPES["train_4k"])
    assert dense_equiv < 6 * kimi.param_count() * 4096 * 256 * 0.2


def test_trace_stats_match_table1():
    for tid, (rmax, avg, sd) in TRACE_SPECS.items():
        ga, gs, gmin, gmax = trace_stats(tid, n=4000)
        assert gmax <= rmax and gmin >= 1
        assert abs(ga - avg) / avg < 0.25, (tid, ga, avg)


def test_simulator_policies_run_and_finish():
    cfg = get_config("mistral-nemo-12b")
    reqs = gen_trace(1, 40, rate=4.0)
    sim_reqs = [SimRequest(i, r.arrival, r.prompt_len, r.output_len)
                for i, r in enumerate(reqs)]
    for policy in ("infinite", "vllm-multi", "vllm-single"):
        sim = make_policy_cluster(cfg, policy, total_chips=16,
                                  chips_per_instance=4)
        out = sim.run([SimRequest(r.req_id, r.arrival, r.prompt_len,
                                  r.output_len) for r in sim_reqs],
                      horizon=500.0)
        assert out["finished"] + out["failed"] == len(sim_reqs)
        assert out["throughput_tok_s"] > 0


def test_simulator_infinite_serves_oversized_request():
    """A request too big for ONE instance must still finish under the
    'infinite' policy (pooled) and fail under vllm-multi."""
    cfg = get_config("mistral-nemo-12b")
    from repro.serving.perfmodel import InstancePerfModel
    cap = InstancePerfModel(cfg, chips=2).kv_tokens_capacity()
    inf = make_policy_cluster(cfg, "infinite", 8, 2)
    out_inf = inf.run([SimRequest(0, 0.0, int(cap * 1.5), 32)],
                      horizon=300.0)
    multi = make_policy_cluster(cfg, "vllm-multi", 8, 2)
    out_multi = multi.run([SimRequest(0, 0.0, int(cap * 1.5), 32)],
                          horizon=300.0)
    assert out_inf["finished"] == 1
    assert out_multi["failed"] == 1
