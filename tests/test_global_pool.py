"""Mesh-shardable global KV pool (serving.globalpool): token identity
vs the per-instance cluster and the dense-cache oracle, zero-copy
donation, StripedMove as intra-tensor slice copies, and spanning
requests feeding the radix prefix cache (insert_chain_multi)."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import decode_step, init_params
from repro.models.prefill import prefill
from repro.serving import (Cluster, Request, SamplingParams,
                           ServingConfig)
import repro.serving.prefixcache as prefixcache_mod

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(arch):
    # float32: the global pool LSE-merges partials in a different order
    # than the per-instance kernels; greedy identity must not hinge on
    # bf16 rounding ties.
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_reference(params, cfg, prompt, n_new):
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
    return out


def _run(params, cfg, prompts, n_new, *, global_pool, **overrides):
    cl = Cluster(params, cfg, ServingConfig.smoke(
        n_instances=3, max_batch=2, pool_blocks=32,
        global_pool=global_pool, **overrides))
    reqs = [Request(prompt=p, sampling=SamplingParams(max_new_tokens=n_new))
            for p in prompts]
    for r in reqs:
        cl.submit(r)
    cl.run_until_done(max_steps=400)
    assert all(r.done for r in reqs), [r.state for r in reqs]
    return cl, [r.output for r in reqs]


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen2-moe-a2.7b"])
def test_global_pool_token_identity_with_movement(arch):
    """Global-pool cluster == per-instance cluster == dense oracle on a
    mix with a spanning request (creditor striping at admission AND
    mid-decode StripedMoves = slice copies inside the one tensor)."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(7)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (5, 40, 12)]
    n_new = 10
    refs = [_greedy_reference(params, cfg, p, n_new) for p in prompts]

    cl_pi, outs_pi = _run(params, cfg, prompts, n_new, global_pool=False)
    assert outs_pi == refs, "per-instance cluster diverged from oracle"

    cl_gp, outs_gp = _run(params, cfg, prompts, n_new, global_pool=True)
    assert outs_gp == refs, "global-pool cluster diverged from oracle"
    assert cl_gp.gpool is not None
    moved = sum(e.stats.kv_moved for e in cl_gp.engines.values())
    assert moved > 0, "expected mid-stream StripedMove legs"


def test_global_pool_zero_copy_and_shared_allocators():
    """PR-4 discipline survives: every decode step reuses the donated
    pool buffer in place, and each engine's rManager aliases the SAME
    RankKVPool object the global table builders read."""
    cfg, params = _setup("olmo-1b")
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (6, 40, 11)]
    cl, _ = _run(params, cfg, prompts, 8, global_pool=True)
    for i, e in cl.engines.items():
        assert e.rmanager.pool is cl.gpool.ranks[i]
        assert e._pool_k is None          # no private pool tensors
    copies = sum(e.stats.pool_copy_steps for e in cl.engines.values())
    steps = sum(e.stats.decode_steps for e in cl.engines.values())
    assert steps > 0 and copies == 0, \
        f"donation broken: {copies}/{steps} steps re-copied the pool"
    with pytest.raises(RuntimeError):
        cl.add_instance(params)           # rank axis is fixed


def test_spanning_request_inserts_into_prefix_cache():
    """Satellite: a request striped across MULTIPLE creditors adopts
    its frames into the radix cache on finish, and a follow-up with the
    same prompt warm-hits it — in global-pool AND per-instance mode."""
    cfg, params = _setup("olmo-1b")
    rng = np.random.default_rng(11)
    prompt = list(rng.integers(0, cfg.vocab_size, size=40))

    inserted = []
    orig = prefixcache_mod.RadixPrefixCache.insert_chain_multi

    def spy(self, placements, tokens):
        inserted.append([inst for inst, _ in placements])
        return orig(self, placements, tokens)

    prefixcache_mod.RadixPrefixCache.insert_chain_multi = spy
    try:
        for gp in (False, True):
            inserted.clear()
            cl, _ = _run(params, cfg, [prompt], 8, global_pool=gp,
                         prefix_cache=True)
            assert inserted, "spanning request never reached the cache"
            assert len(set(inserted[0])) >= 2, \
                "chain was not multi-creditor"
            r1 = Request(prompt=prompt,
                         sampling=SamplingParams(max_new_tokens=8))
            cl.submit(r1)
            cl.run_until_done(max_steps=300)
            assert r1.done
            hits = sum(e.stats.cache_hit_tokens
                       for e in cl.engines.values())
            assert hits > 0, f"no warm hit (global_pool={gp})"
    finally:
        prefixcache_mod.RadixPrefixCache.insert_chain_multi = orig


@pytest.mark.slow
def test_global_pool_shard_map_matches_single_device():
    """Mesh path (8 fake CPU devices, subprocess): shard_map global
    pool == per-instance cluster == dense oracle, dense + moe, 2 and 4
    ranks, with mid-stream moves (remote DMA under GSPMD)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "helpers",
                                      "global_check.py")],
        env=env, capture_output=True, text=True, timeout=1800)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ALL OK" in r.stdout
