"""DistAttention == full attention, over arbitrary sequence partitions.

This is the paper's core mathematical claim (Eq. 1 == Eq. 2+3); we check it
property-style with hypothesis over head layouts (MHA/GQA/MQA), partition
shapes, masks, and dtypes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (
    dist_attention_decode, dist_attention_prefill,
    full_attention_decode, full_attention_prefill,
    merge_partials, micro_attention_decode,
)

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


def _split_points(rng, S, n_parts):
    cuts = sorted(rng.choice(np.arange(1, S), size=n_parts - 1, replace=False)) \
        if n_parts > 1 else []
    return [0] + list(cuts) + [S]


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 3),
    K=st.sampled_from([1, 2, 4]),
    G=st.sampled_from([1, 2, 4]),     # query heads per kv head
    D=st.sampled_from([8, 16]),
    S=st.integers(4, 64),
    n_parts=st.integers(1, 5),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_partition_equivalence(B, K, G, D, S, n_parts, dtype, seed):
    n_parts = min(n_parts, S)
    H = K * G
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, km = jax.random.split(key, 4)
    q = _rand(kq, (B, H, D), dtype)
    k = _rand(kk, (B, S, K, D), dtype)
    v = _rand(kv, (B, S, K, D), dtype)
    mask = jax.random.bernoulli(km, 0.8, (B, S))
    ref = full_attention_decode(q, k, v, mask)

    rng = np.random.default_rng(seed)
    pts = _split_points(rng, S, n_parts)
    parts = [(k[:, a:b], v[:, a:b], mask[:, a:b])
             for a, b in zip(pts[:-1], pts[1:])]
    rng.shuffle(parts)                   # placement order must not matter
    out = dist_attention_decode(q, parts)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@settings(max_examples=15, deadline=None)
@given(
    B=st.integers(1, 2),
    K=st.sampled_from([1, 2]),
    G=st.sampled_from([1, 3]),
    T=st.integers(1, 16),
    S_extra=st.integers(0, 16),
    n_parts=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_partition_equivalence(B, K, G, T, S_extra, n_parts, seed):
    """Chunked causal prefill: queries at [S_past, S_past+T) over split KV."""
    H, D = K * G, 8
    S = T + S_extra                       # total KV = past + current
    n_parts = min(n_parts, S)
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (B, T, H, D))
    k = _rand(kk, (B, S, K, D))
    v = _rand(kv, (B, S, K, D))
    ref = full_attention_prefill(q, k, v, q_offset=S_extra)

    rng = np.random.default_rng(seed)
    pts = _split_points(rng, S, n_parts)
    kv_pos_full = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    parts = [(k[:, a:b], v[:, a:b], kv_pos_full[:, a:b],
              jnp.ones((B, b - a), bool)) for a, b in zip(pts[:-1], pts[1:])]
    rng.shuffle(parts)
    q_pos = S_extra + jnp.arange(T, dtype=jnp.int32)[None].repeat(B, 0)
    out = dist_attention_prefill(q, parts, q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_empty_partition_is_identity():
    key = jax.random.PRNGKey(0)
    q = _rand(key, (2, 4, 8))
    k = _rand(key, (2, 10, 2, 8))
    v = _rand(key, (2, 10, 2, 8))
    mask = jnp.ones((2, 10), bool)
    ref = full_attention_decode(q, k, v, mask)
    # Insert a fully-masked slice — contributes identity to the merge.
    empty_mask = jnp.zeros((2, 3), bool)
    parts = [(k[:, :5], v[:, :5], mask[:, :5]),
             (k[:, :3], v[:, :3], empty_mask),
             (k[:, 5:], v[:, 5:], mask[:, 5:])]
    out = dist_attention_decode(q, parts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_all_masked_yields_zeros_not_nan():
    key = jax.random.PRNGKey(1)
    q = _rand(key, (1, 2, 4))
    k = _rand(key, (1, 6, 2, 4))
    v = _rand(key, (1, 6, 2, 4))
    out = full_attention_decode(q, k, v, jnp.zeros((1, 6), bool))
    assert not np.any(np.isnan(np.asarray(out)))
    np.testing.assert_allclose(np.asarray(out), 0.0)


def test_merge_partials_matches_sequential_combine():
    key = jax.random.PRNGKey(2)
    q = _rand(key, (2, 4, 8))
    parts = []
    for i in range(4):
        k = _rand(jax.random.fold_in(key, i), (2, 7, 2, 8))
        v = _rand(jax.random.fold_in(key, 100 + i), (2, 7, 2, 8))
        parts.append(micro_attention_decode(q, k, v, jnp.ones((2, 7), bool)))
    o = jnp.stack([p[0] for p in parts])
    m = jnp.stack([p[1] for p in parts])
    l = jnp.stack([p[2] for p in parts])
    og, mg, lg = merge_partials(o, m, l, axis=0)
    from repro.core import combine, empty_partial, finalize
    acc = empty_partial((2, 4, 8), (2, 4))
    for p in parts:
        acc = combine(acc, p)
    np.testing.assert_allclose(np.asarray(finalize(og, lg)),
                               np.asarray(finalize(acc[0], acc[2])), atol=1e-6)
