"""Per-arch smoke tests: reduced config, one forward + one decode step on
CPU; asserts output shapes and no NaNs. Also checks decode-vs-forward
consistency (teacher forcing) for every family's cache path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.model import (decode_step, forward, init_decode_state,
                                init_params)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch, rng):
    cfg = get_smoke_config(arch)
    params = init_params(rng, cfg)
    B, T = 2, 16
    if cfg.modality in ("vlm", "audio"):
        # Modality frontend stub: precomputed patch/frame embeddings.
        embeds = jax.random.normal(rng, (B, T, cfg.d_model),
                                   jnp.float32).astype(cfg.dtype)
        logits, aux = forward(params, cfg, embeds=embeds)
    else:
        tokens = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
        logits, aux = forward(params, cfg, tokens)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    assert not np.isnan(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch, rng):
    """Token-by-token decode with cache == full forward (teacher forcing)."""
    cfg = get_smoke_config(arch)
    params = init_params(rng, cfg)
    B, T = 2, 12
    tokens = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    ref_logits, _ = forward(params, cfg, tokens, capacity_factor=-1.0)

    state = init_decode_state(cfg, B, max_len=T + 4)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))
    outs = []
    for t in range(T):
        logits, state = step(params, state, tokens[:, t])
        outs.append(logits)
    got = jnp.stack(outs, axis=1)
    tol = 5e-2 if cfg.dtype == "bfloat16" else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref_logits, np.float32),
                               atol=tol, rtol=tol)


def test_train_step_no_nans(rng):
    """One SGD step on a tiny dense model: loss finite, grads finite."""
    cfg = get_smoke_config("olmo-1b")
    params = init_params(rng, cfg)
    tokens = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)

    def loss_fn(p):
        logits, aux = forward(p, cfg, tokens[:, :-1])
        tgt = tokens[:, 1:]
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(lp, tgt[..., None], -1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
               for g in leaves)
