"""prefill-into-cache + distributed (local/remote split) decode correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import decode_step, forward, init_decode_state
from repro.models.model import init_params
from repro.models.prefill import decode_step_dist, prefill, write_slot


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen2-moe-a2.7b",
                                  "recurrentgemma-9b", "xlstm-350m"])
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, T, n_gen = 2, 10, 4
    total = T + n_gen
    tokens = jax.random.randint(key, (B, total), 0, cfg.vocab_size)

    ref_logits, _ = forward(params, cfg, tokens, capacity_factor=-1.0)

    logits, state = prefill(params, cfg, tokens[:, :T], max_len=total + 2)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref_logits[:, T - 1], np.float32),
                               atol=5e-2, rtol=5e-2)
    for t in range(T, total):
        logits, state = decode_step(params, cfg, state, tokens[:, t])
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   np.asarray(ref_logits[:, t], np.float32),
                                   atol=5e-2, rtol=5e-2)


def test_write_slot_roundtrip():
    cfg = get_smoke_config("olmo-1b")
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    T = 6
    tok = jax.random.randint(key, (1, T), 0, cfg.vocab_size)
    _, req_state = prefill(params, cfg, tok, max_len=16)
    batch_state = init_decode_state(cfg, 4, 16)
    batch_state = write_slot(batch_state, 2, req_state, cfg)
    assert int(batch_state.lens[2]) == T
    np.testing.assert_array_equal(np.asarray(batch_state.kv_k[:, 2]),
                                  np.asarray(req_state.kv_k[:, 0]))


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen2-moe-a2.7b"])
def test_dist_decode_local_remote_split_matches_plain(arch):
    """KV split across a local ring (tail) + remote span (prefix) must give
    the same logits as a single full local cache — the paper's core
    serving equivalence."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    B, T = 2, 24
    maxlen = 16            # ring keeps [T-16, T) after prefill
    start_val = T - maxlen
    tokens = jax.random.randint(key, (B, T + 3), 0, cfg.vocab_size)

    # Reference: plain decode with a big cache.
    _, full_state = prefill(params, cfg, tokens[:, :T], max_len=T + 8)
    ref_state = full_state
    ref_logits = []
    for t in range(T, T + 3):
        lg, ref_state = decode_step(params, cfg, ref_state, tokens[:, t])
        ref_logits.append(lg)

    # Distributed: ring cache of 16 + remote prefix [0, start_i).
    # Each write evicts the ring's oldest position, so the runtime ships
    # it to a creditor first — here the remote span simply grows with i
    # (its KV values are identical to what prefill computed).
    _, ring_state = prefill(params, cfg, tokens[:, :T], max_len=maxlen)
    remote_k = full_state.kv_k[:, :, :start_val + 3]   # [L,B,S_r,K,hd]
    remote_v = full_state.kv_v[:, :, :start_val + 3]
    st = ring_state
    for i, t in enumerate(range(T, T + 3)):
        start_i = T + i + 1 - maxlen                   # oldest pos in ring
        start = jnp.full((B,), start_i, jnp.int32)
        rlen = jnp.full((B,), start_i, jnp.int32)
        lg, st = decode_step_dist(params, cfg, st, tokens[:, t], start,
                                  remote_k, remote_v, rlen)
        np.testing.assert_allclose(np.asarray(lg, np.float32),
                                   np.asarray(ref_logits[i], np.float32),
                                   atol=5e-2, rtol=5e-2)


def test_dist_decode_zero_remote_is_plain():
    cfg = get_smoke_config("olmo-1b")
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    B, T = 2, 8
    tokens = jax.random.randint(key, (B, T + 2), 0, cfg.vocab_size)
    _, state = prefill(params, cfg, tokens[:, :T], max_len=32)
    lg_ref, _ = decode_step(params, cfg, state, tokens[:, T])
    L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    rk = jnp.zeros((L, B, 4, K, hd), jnp.dtype(cfg.dtype))
    lg, _ = decode_step_dist(params, cfg, state, tokens[:, T],
                             jnp.zeros((B,), jnp.int32), rk, rk,
                             jnp.zeros((B,), jnp.int32))
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(lg_ref, np.float32),
                               atol=2e-2, rtol=2e-2)
