"""Overload survival (ISSUE-8): preemptive pause/host-spill scheduling.

Covers the tentpole's correctness surface:

  (a) pause/resume round trip — a running request paused to the
      preempt tier and resumed through the paged path emits EXACTLY
      the tokens an unpreempted oracle emits (byte-identical KV), in
      both per-instance and global-pool modes;
  (b) a pause releases every device resource exactly once (allocator
      state returns to pre-admission; creditor spans never
      double-free) and the resume restores a clean steady state;
  (c) a mid-prefill pause aborts at the chunk boundary with the exact
      cancel-style rollback but re-queues the request (WAITING, flag
      cleared, preemption counted) instead of retiring it;
  (d) the EWMA arrival estimator converges on the live trace and is
      pushed into the scheduler before planning (replacing the static
      ``avg_new_req_len`` knob);
  (e) SLO-aware victim selection prefers no-deadline (infinite-slack)
      victims and respects the urgency ordering; the server-level
      preempt-for-queue path serves an urgent arrival by pausing a
      best-effort victim and later resuming it;
  (f) cancel-while-paused retires the parked request and frees its
      preempt-tier frames.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import decode_step, init_params
from repro.models.prefill import prefill
from repro.serving import (LLMServer, RequestState, SamplingParams,
                           ServingConfig)
from repro.serving.config import OverloadPolicy
from repro.serving.gmanager import ArrivalEstimator
import jax.numpy as jnp


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_reference(params, cfg, prompt, n_new):
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
    return out


def _alloc_snapshot(cluster):
    out = {}
    for i, e in cluster.engines.items():
        a = e.rmanager.pool.alloc
        out[i] = (a.used_count, a.reserved, sorted(a._free),
                  {r: list(rb.blocks)
                   for r, rb in e.rmanager.pool.requests.items()})
    return out


def _overload_server(params, cfg, *, global_pool=False, **overrides):
    policy = overrides.pop("policy", OverloadPolicy(enabled=True))
    return LLMServer(params, cfg, ServingConfig.smoke(
        overload=policy, global_pool=global_pool, **overrides))


# ------------------------------------------------------------------ #
# (a) pause/resume token identity vs the unpreempted oracle
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("global_pool", [False, True],
                         ids=["per-instance", "global-pool"])
def test_pause_resume_token_identity(setup, global_pool):
    cfg, params = setup
    rng = np.random.default_rng(80)
    prompt = rng.integers(0, cfg.vocab_size, 12).tolist()
    n_new = 14
    server = _overload_server(params, cfg, global_pool=global_pool)
    pre = server.cluster.preemptor
    assert pre is not None

    h = server.submit(prompt, SamplingParams(max_new_tokens=n_new))
    req = h._req
    for _ in range(5):
        server.step()
    assert req.state == RequestState.RUNNING and len(req.output) >= 5

    assert pre.pause(req)
    assert req.state == RequestState.PAUSED
    assert req.slot is None and not h.done
    assert pre.tier.used_blocks > 0

    # With no queue and free capacity the very next step resumes it;
    # result() drives to completion through the resume path.
    out = h.result()
    assert req.state == RequestState.FINISHED
    assert req.preemptions == 1
    assert pre.stats.resumes == 1 and not pre.paused
    assert pre.tier.used_blocks == 0          # frames dropped at resume
    assert out == _greedy_reference(params, cfg, prompt, n_new)


# ------------------------------------------------------------------ #
# (a2) spanning request paused MID-DECODE: the live local/creditor
# split has drifted from admission's quota math (decode appends grew
# the local tail), and the resume lands in the same step as the
# pause's queued finished event. Token identity requires BOTH the
# recorded-layout reproduction in resume_paused and the drain skipping
# live requests — each regression flips tokens on this scenario.
# ------------------------------------------------------------------ #
def test_pause_resume_spanning_mid_decode_identity(setup):
    cfg, params = setup
    rng = np.random.default_rng(99)
    prompt = rng.integers(0, cfg.vocab_size, 40).tolist()  # > quota: spans
    n_new = 8
    server = _overload_server(params, cfg)
    cl = server.cluster

    h = server.submit(prompt, SamplingParams(max_new_tokens=n_new))
    req = h._req
    for _ in range(3):
        server.step()
    assert req.state == RequestState.RUNNING
    assert any(e.rmanager.is_hosting(req.req_id)
               for e in cl.engines.values()), "expected a creditor span"

    assert cl.preemptor.pause(req)
    rec = cl.preemptor.paused[req.req_id]
    assert rec.remote_layout, "paused chain should record creditor runs"

    out = h.result()                  # resumes next step, runs to finish
    assert req.preemptions == 1 and cl.preemptor.stats.resumes == 1
    assert out == _greedy_reference(params, cfg, prompt, n_new)

    # Same-step resume must survive the pause's finished-event drain:
    # nothing leaked, nothing double-released.
    server.step()
    for e in cl.engines.values():
        a = e.rmanager.pool.alloc
        assert a.reserved == 0 and a.used_count == 0
    assert cl.preemptor.tier.used_blocks == 0


# ------------------------------------------------------------------ #
# (b) exact release at pause: allocator returns to pre-admission state
# ------------------------------------------------------------------ #
def test_pause_releases_everything_exactly_once(setup):
    cfg, params = setup
    rng = np.random.default_rng(81)
    server = _overload_server(
        params, cfg, max_local_len=16, block_size=4, pool_blocks=32,
        policy=OverloadPolicy(enabled=True, min_pause_s=600.0))
    cl = server.cluster
    before = _alloc_snapshot(cl)

    # 40-token prompt with a 16-token quota: admission stripes a
    # creditor span, so the pause must also release hosted blocks.
    prompt = rng.integers(0, cfg.vocab_size, 40).tolist()
    h = server.submit(prompt, SamplingParams(max_new_tokens=6))
    req = h._req
    server.step()
    assert req.state == RequestState.RUNNING
    assert any(e.rmanager.is_hosting(req.req_id)
               for e in cl.engines.values()
               if e.inst_id != cl.engines[0].inst_id or True)

    assert cl.preemptor.pause(req)
    # Device state is EXACTLY the pre-admission state: slot, local
    # blocks, cache pins and creditor spans all released, once.
    assert _alloc_snapshot(cl) == before
    # min_pause_s keeps it parked: the finished-event drain at step end
    # must not double-release, and no step advances it.
    server.step()
    assert _alloc_snapshot(cl) == before
    assert req.state == RequestState.PAUSED

    cl.preemptor.policy = OverloadPolicy(enabled=True)  # allow resume
    out = h.result()
    assert req.state == RequestState.FINISHED
    assert out == _greedy_reference(params, cfg, prompt, 6)
    # Steady state after finish: everything released again.
    server.step()
    assert _alloc_snapshot(cl) == before


# ------------------------------------------------------------------ #
# (c) mid-prefill pause: exact rollback, request survives as WAITING
# ------------------------------------------------------------------ #
def test_midprefill_pause_rolls_back_and_requeues(setup):
    cfg, params = setup
    rng = np.random.default_rng(82)
    server = _overload_server(params, cfg)
    cl = server.cluster
    before = _alloc_snapshot(cl)

    prompt = rng.integers(0, cfg.vocab_size, 24).tolist()
    h = server.submit(prompt, SamplingParams(max_new_tokens=4))
    req = h._req
    req.pause_requested = True       # lands before the first chunk
    server.step()
    assert req.state == RequestState.WAITING
    assert not req.pause_requested and req.preemptions == 1
    assert _alloc_snapshot(cl) == before

    out = h.result()                 # re-admits (re-prefills) cleanly
    assert out == _greedy_reference(params, cfg, prompt, 4)


# ------------------------------------------------------------------ #
# (d) EWMA arrival estimator feeds Algorithm-1 planning
# ------------------------------------------------------------------ #
def test_arrival_estimator_converges_and_feeds_scheduler(setup):
    est = ArrivalEstimator(alpha=0.5, init_len=100)
    assert est.rate_hz == 0.0 and est.avg_new_req_len == 100
    t = 0.0
    for _ in range(40):
        est.observe(t, 30)
        t += 0.25                    # 4 req/s, 30-token footprint
    assert est.avg_new_req_len == 30
    assert est.rate_hz == pytest.approx(4.0, rel=1e-3)

    cfg, params = setup
    server = _overload_server(params, cfg)
    gm = server.cluster.gmanager
    assert gm.scheduler.avg_new_len == server.config.avg_new_req_len
    for i in range(6):
        server.submit([1, 2, 3], SamplingParams(max_new_tokens=5),
                      arrival_time=float(i))
    server.step()                    # plan round pushes the estimate
    server.cluster.gmanager.plan_moves()
    assert gm.scheduler.avg_new_len == gm.arrivals.avg_new_req_len
    assert gm.scheduler.arrival_rate_hz == gm.arrivals.rate_hz
    assert gm.arrivals.avg_new_req_len != server.config.avg_new_req_len
    server.drain()


# ------------------------------------------------------------------ #
# (e) SLO-aware victims + server-level preempt-for-queue
# ------------------------------------------------------------------ #
def test_victim_ranking_prefers_slack(setup):
    cfg, params = setup
    server = _overload_server(params, cfg, n_instances=1, max_batch=2)
    rng = np.random.default_rng(83)
    slack_h = server.submit(rng.integers(0, cfg.vocab_size, 8).tolist(),
                            SamplingParams(max_new_tokens=20))
    tight_h = server.submit(rng.integers(0, cfg.vocab_size, 8).tolist(),
                            SamplingParams(max_new_tokens=20),
                            deadline_s=0.75)
    for _ in range(3):
        server.step()
    import time
    ranked = server.cluster.preemptor.rank_victims(time.monotonic())
    assert [r.req_id for _, r in ranked][0] == slack_h.req_id
    assert ranked[0][0] == float("inf")      # no deadline => max slack
    # The deadline-carrying request's slack is finite and charged the
    # preemption round trip.
    tight = dict((r.req_id, s) for s, r in ranked)
    assert tight[tight_h.req_id] < float("inf")
    server.drain()


def test_urgent_arrival_preempts_and_victim_resumes(setup):
    cfg, params = setup
    rng = np.random.default_rng(84)
    server = _overload_server(params, cfg, n_instances=1, max_batch=1)
    pre = server.cluster.preemptor
    bg_prompt = rng.integers(0, cfg.vocab_size, 10).tolist()
    bg = server.submit(bg_prompt, SamplingParams(max_new_tokens=16))
    for _ in range(4):
        server.step()
    assert bg._req.state == RequestState.RUNNING

    urgent = server.submit(rng.integers(0, cfg.vocab_size, 6).tolist(),
                           SamplingParams(max_new_tokens=4),
                           priority=1, deadline_s=60.0)
    server.step()
    # The background request was paused and the urgent one took its slot.
    assert bg._req.state == RequestState.PAUSED
    assert pre.stats.preemptions == 1
    urgent_out = urgent.result()
    assert len(urgent_out) == 4

    bg_out = bg.result()
    assert pre.stats.resumes == 1
    assert bg._req.preemptions == 1
    assert bg_out == _greedy_reference(params, cfg, bg_prompt, 16)

    m = server.metrics
    assert m["preemptions"] == 1.0 and m["preempt_resumes"] == 1.0
    assert m["paused_now"] == 0.0
    fm = LLMServer.frontend_metrics([bg, urgent], wall_s=1.0)
    assert fm["preempted"] == 1.0
    assert fm["deadline_goodput"] == 1.0
    assert fm["slo_attainment"] == 1.0


# ------------------------------------------------------------------ #
# (f) cancel while paused
# ------------------------------------------------------------------ #
def test_cancel_while_paused(setup):
    cfg, params = setup
    rng = np.random.default_rng(85)
    server = _overload_server(params, cfg)
    h = server.submit(rng.integers(0, cfg.vocab_size, 10).tolist(),
                      SamplingParams(max_new_tokens=30))
    for _ in range(3):
        server.step()
    pre = server.cluster.preemptor
    assert pre.pause(h._req)
    assert pre.tier.used_blocks > 0
    assert server.cancel(h.req_id)
    assert h.status == RequestState.CANCELLED and h.done
    assert pre.tier.used_blocks == 0 and not pre.paused
    server.step()                     # no resurrection, no double free
    assert h.status == RequestState.CANCELLED
