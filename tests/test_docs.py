"""Documentation gates (no jax required — runs in the CI docs job).

Three checks keep the docs from rotting:

  * knob drift — the ``docs/ARCHITECTURE.md`` knob-reference tables and
    the ``ServingConfig``/``OverloadPolicy`` dataclasses must agree
    field-for-field, in BOTH directions (a new knob without a doc row
    fails, and so does a doc row for a removed knob). ``config.py`` is
    imported standalone so this file never pulls in jax.
  * internal links — every relative markdown link in README.md and
    docs/ARCHITECTURE.md resolves to a real file.
  * docstring coverage — an AST mirror of the ruff D100-D104 subset
    enforced on ``src/repro/serving/`` (module/class/function/package
    docstrings for public names), so the gate holds even where ruff
    isn't installed.
"""
import ast
import dataclasses
import importlib.util
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
ARCH = REPO / "docs" / "ARCHITECTURE.md"
README = REPO / "README.md"
SERVING = REPO / "src" / "repro" / "serving"


def _load_config_module():
    """Import serving/config.py standalone (it has no jax imports)."""
    spec = importlib.util.spec_from_file_location(
        "serving_config_standalone", SERVING / "config.py")
    mod = importlib.util.module_from_spec(spec)
    # Registered so dataclasses can resolve the module's (string,
    # because of ``from __future__ import annotations``) annotations.
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


def _table_fields(section: str) -> set:
    """Field names from the knob table under ``### `section```."""
    text = ARCH.read_text()
    m = re.search(rf"### `{section}`\n(.*?)(?:\n### |\n## |\Z)", text,
                  re.DOTALL)
    assert m, f"ARCHITECTURE.md lost its `{section}` knob table"
    return set(re.findall(r"^\| `(\w+)` \|", m.group(1), re.MULTILINE))


# ------------------------------------------------------------------ #
# knob drift: dataclass fields <-> ARCHITECTURE.md tables
# ------------------------------------------------------------------ #
def test_serving_config_knobs_match_architecture_doc():
    mod = _load_config_module()
    code = {f.name for f in dataclasses.fields(mod.ServingConfig)}
    doc = _table_fields("ServingConfig")
    assert code - doc == set(), \
        f"knobs missing from docs/ARCHITECTURE.md: {sorted(code - doc)}"
    assert doc - code == set(), \
        f"docs/ARCHITECTURE.md rows for removed knobs: {sorted(doc - code)}"


def test_overload_policy_knobs_match_architecture_doc():
    mod = _load_config_module()
    code = {f.name for f in dataclasses.fields(mod.OverloadPolicy)}
    doc = _table_fields("OverloadPolicy")
    assert code - doc == set(), \
        f"knobs missing from docs/ARCHITECTURE.md: {sorted(code - doc)}"
    assert doc - code == set(), \
        f"docs/ARCHITECTURE.md rows for removed knobs: {sorted(doc - code)}"


def test_fault_policy_knobs_match_architecture_doc():
    mod = _load_config_module()
    code = {f.name for f in dataclasses.fields(mod.FaultPolicy)}
    doc = _table_fields("FaultPolicy")
    assert code - doc == set(), \
        f"knobs missing from docs/ARCHITECTURE.md: {sorted(code - doc)}"
    assert doc - code == set(), \
        f"docs/ARCHITECTURE.md rows for removed knobs: {sorted(doc - code)}"


def test_request_states_all_documented():
    """Every RequestState value appears in the lifecycle section."""
    tree = ast.parse((SERVING / "request.py").read_text())
    states = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "RequestState":
            for stmt in node.body:
                if isinstance(stmt, ast.Assign):
                    states.extend(t.id for t in stmt.targets
                                  if isinstance(t, ast.Name))
    assert states, "RequestState enum not found"
    text = ARCH.read_text()
    missing = [s for s in states if s not in text]
    assert not missing, \
        f"lifecycle states missing from ARCHITECTURE.md: {missing}"


# ------------------------------------------------------------------ #
# internal markdown links resolve
# ------------------------------------------------------------------ #
def test_internal_links_resolve():
    broken = []
    for doc in (README, ARCH):
        for target in re.findall(r"\]\(([^)#]+)(?:#[^)]*)?\)",
                                 doc.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if not (doc.parent / target).resolve().exists():
                broken.append(f"{doc.name} -> {target}")
    assert not broken, f"broken internal links: {broken}"


# ------------------------------------------------------------------ #
# docstring coverage: AST mirror of the ruff D100-D104 serving gate
# ------------------------------------------------------------------ #
def _missing_docstrings(path: pathlib.Path):
    tree = ast.parse(path.read_text())
    missing = []
    if ast.get_docstring(tree) is None:        # D100 / D104
        missing.append(f"{path.name}:1 module docstring")

    def walk(node, private, prefix):
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef, ast.ClassDef)):
                continue
            name = child.name
            dunder = name.startswith("__") and name.endswith("__")
            priv = private or name.startswith("_")
            if (not priv and not dunder
                    and ast.get_docstring(child) is None):
                kind = ("class" if isinstance(child, ast.ClassDef)
                        else "def")                    # D101-D103
                missing.append(
                    f"{path.name}:{child.lineno} {kind} {prefix}{name}")
            walk(child, priv, prefix + name + ".")

    walk(tree, False, "")
    return missing


def test_serving_public_api_has_docstrings():
    missing = []
    for py in sorted(SERVING.glob("*.py")):
        missing.extend(_missing_docstrings(py))
    assert not missing, \
        "public serving names without docstrings:\n  " + \
        "\n  ".join(missing)
