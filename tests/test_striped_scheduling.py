"""Striped span scheduling (multi-creditor Algorithm 1).

Covers the ISSUE-3 acceptance criteria: (a) end-to-end, a request whose
movable prefix exceeds ANY single creditor's free blocks is striped
across >= 2 creditors by the decode-time planner with token-identical
greedy output vs the single-pool oracle (including the symmetric
reclaim path firing mid-run), (b) a striped plan whose legs cannot all
be reserved is rejected with allocator state restored exactly, and
(c) hypothesis property tests: plans never over-commit a creditor's
free blocks, debtor/creditor roles stay disjoint, and all-or-nothing
reservation rollback is exact.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.serving import InstancePerfModel
from repro.serving.protocol import MoveKVCache, MoveLeg, MoveResult
from repro.serving.rmanager import RManager
from repro.serving.scheduler import GreedyScheduler, InstanceView
from repro.serving.cluster import reserve_all_or_nothing

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False


# ------------------------------------------------------------------ #
# End-to-end: decode-time striping across >= 2 creditors, exact decode
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("seed", [7])
def test_decode_time_striping_across_two_creditors_exact(seed):
    import jax
    import jax.numpy as jnp

    from repro.models.model import decode_step, init_params
    from repro.models.prefill import prefill
    from repro.serving import Cluster, Request, RequestState, SamplingParams

    # float32 so LSE-merge rounding cannot flip near-tie argmaxes of the
    # random-init smoke model (the comparison is token-exactness, not
    # numerics — the bf16 paths are oracle-checked in test_paged_prefill).
    cfg = dataclasses.replace(get_smoke_config("olmo-1b"),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    T, n_new = 40, 16
    prompt = list(rng.integers(0, cfg.vocab_size, T))
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens, max_len=T + n_new + 2)
    ref = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([ref[-1]], jnp.int32))
        ref.append(int(jnp.argmax(lg[0])))

    # alpha_hop=0: at smoke scale the per-span hop latency otherwise
    # dwarfs the microscopic KV times and the model (correctly) refuses
    # to stripe; avg_new_req_len=4 makes freed blocks admit modeled work.
    perf = InstancePerfModel(cfg, alpha_hop=0.0)
    from repro.serving import ServingConfig
    cl = Cluster(params, cfg, ServingConfig.smoke(
        n_instances=3, max_batch=2, max_local_len=64, pool_blocks=16,
        block_size=4, schedule_every=4, avg_new_req_len=4,
        move_chunk_tokens=16, prefill_chunk=32), perf=perf)
    executed = []
    orig_exec = cl._execute_move

    def spy(mv):
        res = orig_exec(mv)
        executed.append((mv.kind, [(leg.dst_inst, leg.num_blocks)
                                   for leg in mv.legs], res))
        return res
    cl._execute_move = spy

    req = Request(prompt=prompt,
                  sampling=SamplingParams(max_new_tokens=n_new))
    cl.submit(req)
    cl.step()                                  # admission (local only)
    owner_id = next(i for i, e in cl.engines.items() if req in e.running)
    owner = cl.engines[owner_id]
    assert not owner.remote_insts.get(req.req_id), \
        "prompt must be admitted fully locally (decode-time test)"
    # Ballast shrinks each creditor to 4 free blocks: the request's
    # movable prefix (>= 9 full blocks) exceeds ANY single creditor.
    for i, e in cl.engines.items():
        if i != owner_id:
            assert e.rmanager.pool.append_tokens(900 + i, 12 * 4)
            free = e.rmanager.pool.alloc.free_count
            assert free * 4 < owner.local_tokens(req) - 4
    cl.step()
    cl.step()
    # The planner's view now warrants a SINGLE multi-leg striped plan.
    plans = [mv for mv in cl.gmanager.plan_moves()
             if mv.req_id == req.req_id]
    assert plans and len(plans[0].legs) >= 2, \
        f"expected a >=2-leg striped plan, got {plans}"

    cl.run_until_done(max_steps=300)
    assert req.state == RequestState.FINISHED
    assert req.output == ref, "striped decode diverged from oracle"
    offloads = [e for e in executed
                if e[0] == "offload" and e[2] == MoveResult.OK]
    assert any(len(legs) >= 2 for _, legs, _ in offloads), \
        "no striped (multi-leg) offload was executed"
    dsts = {d for _, legs, _ in offloads for d, _ in legs}
    assert len(dsts) >= 2, "prefix did not stripe across >=2 creditors"
    # The creditors became memory-stressed hosting the span, so the
    # symmetric reclaim path must also have fired — and stayed exact.
    assert any(e[0] == "reclaim" and e[2] == MoveResult.OK
               for e in executed), "reclaim path never executed"
    for e in cl.engines.values():
        assert e.rmanager.pool.alloc.reserved == 0


# ------------------------------------------------------------------ #
# All-or-nothing: a stripe with an unreservable leg rolls back exactly
# ------------------------------------------------------------------ #
def test_striped_move_rejected_leg_rolls_back_exactly():
    import jax

    from repro.models.model import init_params
    from repro.serving import Cluster, Request, SamplingParams, ServingConfig

    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    cl = Cluster(params, cfg, ServingConfig.smoke(
        n_instances=3, max_batch=2, max_local_len=64, pool_blocks=16,
        block_size=4, schedule_every=10 ** 9, move_chunk_tokens=16,
        prefill_chunk=32))
    req = Request(prompt=list(rng.integers(0, cfg.vocab_size, 40)),
                  sampling=SamplingParams(max_new_tokens=4))
    cl.submit(req)
    cl.step()
    owner_id = next(i for i, e in cl.engines.items() if req in e.running)
    others = [i for i in cl.engines if i != owner_id]
    # Second creditor has only 2 free blocks: its 4-block leg must fail
    # AND the first creditor's already-made reservation must be undone.
    cl.engines[others[1]].rmanager.pool.append_tokens(901, 14 * 4)

    def snapshot():
        out = {}
        for i, e in cl.engines.items():
            a = e.rmanager.pool.alloc
            out[i] = (a.used_count, a.reserved, sorted(a._free),
                      {r: list(rb.blocks) for r, rb
                       in e.rmanager.pool.requests.items()})
        return out

    before = snapshot()
    res = cl._execute_move(MoveKVCache(
        req.req_id, owner_id,
        [MoveLeg(others[0], 4), MoveLeg(others[1], 4)]))
    assert res == MoveResult.REJECTED
    assert snapshot() == before, \
        "failed stripe did not restore allocator state exactly"


# ------------------------------------------------------------------ #
# Property tests (hypothesis)
# ------------------------------------------------------------------ #
if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(data=st.data())
    def test_plans_never_overcommit_and_roles_disjoint(data):
        cfg = get_config("olmo-1b")
        sched = GreedyScheduler(InstancePerfModel(cfg), block_size=16,
                                beta_thres=data.draw(
                                    st.integers(0, 32), label="beta"),
                                mem_util_thres=data.draw(
                                    st.floats(0.3, 0.95), label="util"),
                                max_stripes=data.draw(
                                    st.integers(1, 6), label="stripes"),
                                avg_new_req_len=data.draw(
                                    st.sampled_from([16, 64, 512]),
                                    label="avg_len"))
        n = data.draw(st.integers(2, 6), label="n")
        views = []
        for i in range(n):
            total = data.draw(st.integers(8, 256), label=f"total{i}")
            used = data.draw(st.integers(0, total), label=f"used{i}")
            reqs = {}
            blocks_left = used
            for j in range(data.draw(st.integers(0, 3), label=f"nr{i}")):
                if blocks_left <= 0:
                    break
                blk = data.draw(st.integers(1, blocks_left),
                                label=f"blk{i}_{j}")
                own = data.draw(st.booleans(), label=f"own{i}_{j}")
                reqs[i * 100 + j] = (blk * 16, blk, own)
                blocks_left -= blk
            hosted = sum(b for (_, b, own) in reqs.values()
                         if not own) * 16
            views.append(InstanceView(
                inst_id=i,
                batch_size=data.draw(st.integers(0, 48), label=f"b{i}"),
                mem_blocks_total=total, mem_blocks_used=used,
                requests=reqs, hosted_tokens=hosted))
        free_before = {v.inst_id: v.free_blocks for v in views}
        import copy
        views_before = copy.deepcopy(views)
        moves = sched.plan(views)
        # plan() never mutates its input views.
        assert views == views_before
        # No creditor is committed past its free blocks (across ALL
        # plans of the round combined), debtors keep >= 1 block of every
        # offloaded request, and no plan repeats a destination.
        incoming = {}
        for m in moves:
            dsts = [leg.dst for leg in m.legs]
            assert len(dsts) == len(set(dsts)), "plan repeats a creditor"
            assert m.src not in dsts
            for leg in m.legs:
                assert leg.num_blocks > 0
                incoming[leg.dst] = incoming.get(leg.dst, 0) \
                    + leg.num_blocks
        freed = {}
        for m in moves:
            if m.kind == "reclaim":
                freed[m.src] = freed.get(m.src, 0) + m.num_blocks
        for dst, n_in in incoming.items():
            assert n_in <= free_before[dst] + freed.get(dst, 0), \
                f"creditor {dst} over-committed"
        # Offload sources and offload destinations are disjoint roles.
        srcs = {m.src for m in moves if m.kind == "offload"}
        off_dsts = {leg.dst for m in moves if m.kind == "offload"
                    for leg in m.legs}
        assert not (srcs & off_dsts)
        # An offload never moves a request's entire span (tail stays).
        by_id = {v.inst_id: v for v in views}
        for m in moves:
            if m.kind == "offload":
                _, blk, _ = by_id[m.src].requests[m.req_id]
                assert m.num_blocks < blk

    @settings(max_examples=60, deadline=None)
    @given(data=st.data())
    def test_all_or_nothing_reservation_rollback_exact(data):
        """reserve_all_or_nothing: on ANY refused leg the allocators of
        every destination are restored exactly (used, reserved, free)."""
        n_dst = data.draw(st.integers(1, 4), label="n_dst")
        rms = []
        for i in range(n_dst):
            rm = RManager(i, num_blocks=data.draw(st.integers(1, 16),
                                                  label=f"nb{i}"),
                          block_size=4)
            fill = data.draw(
                st.integers(0, rm.pool.alloc.num_blocks), label=f"f{i}")
            if fill:
                rm.pool.append_tokens(500 + i, fill * 4)
            pre = data.draw(
                st.integers(0, 3), label=f"pre{i}")
            rm.pool.alloc.reserved = min(pre, rm.pool.alloc.free_count)
            rms.append(rm)
        legs = [(rms[data.draw(st.integers(0, n_dst - 1),
                               label=f"leg_dst{j}")],
                 data.draw(st.integers(1, 8), label=f"leg_n{j}"))
                for j in range(data.draw(st.integers(1, 5),
                                         label="n_legs"))]

        def state():
            return [(rm.pool.alloc.used_count, rm.pool.alloc.reserved,
                     sorted(rm.pool.alloc._free)) for rm in rms]

        before = state()
        ok = reserve_all_or_nothing(req_id=1, legs=legs)
        if ok:
            # Every leg reserved; cancelling them all restores state.
            for rm, n in legs:
                rm.cancel_move_in(n)
            assert state() == before
        else:
            assert state() == before, \
                "refused stripe left reservations behind"
else:
    @pytest.mark.skip(reason="property tests need hypothesis "
                      "(pip install -r requirements-dev.txt)")
    def test_striped_property_suite_requires_hypothesis():
        """Visible placeholder: the over-commit / disjoint-roles /
        rollback property tests above were not collected."""
