"""Mesh-level serving step correctness (8 fake devices via subprocess —
the main test process must keep its single-device view)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_sharded_decode_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "helpers",
                                      "sharded_check.py")],
        env=env, capture_output=True, text=True, timeout=1800)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ALL OK" in r.stdout
