"""Streaming paged prefill: chunked admission straight into block pools.

Covers the ISSUE-2 acceptance criteria: (a) chunked paged prefill
(``prefill_chunk_paged``) reproduces the dense ``prefill()`` oracle's
logits AND pool rows for several chunk sizes on dense and moe configs,
(b) the dense/moe serving admission path never materializes a dense
``[L, 1, T, K, hd]`` prompt cache, and (c) a prompt longer than
``max_local_len`` whose prefix cannot fit on one creditor stripes across
two or more creditors at admission time and still decodes exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.prefill as prefill_mod
import repro.serving.engine as engine_mod
from repro.configs import get_smoke_config
from repro.models.model import decode_step, init_params
from repro.models.prefill import prefill, prefill_chunk_paged
from repro.serving import (Cluster, InstanceEngine, Request, RequestState,
                           SamplingParams, ServingConfig)
from repro.serving.kvpool import (RankKVPool, prefix_tables, read_pool_rows,
                                  rows_for_token_range, scatter_pool_rows,
                                  table_bucket)

_SETUPS = {}


def _setup(arch):
    if arch not in _SETUPS:
        cfg = get_smoke_config(arch)
        _SETUPS[arch] = (cfg, init_params(jax.random.PRNGKey(0), cfg))
    return _SETUPS[arch]


def _greedy_reference(params, cfg, prompt, n_new):
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
    return out


# ------------------------------------------------------------------ #
# kvpool addressing helpers
# ------------------------------------------------------------------ #
def test_rows_for_token_range():
    blk, off = rows_for_token_range([7, 3, 9], 4, 2, 9)
    np.testing.assert_array_equal(blk, [7, 7, 3, 3, 3, 3, 9])
    np.testing.assert_array_equal(off, [2, 3, 0, 1, 2, 3, 0])


def test_scatter_pool_rows_mid_block():
    L, NB, bs, K, hd = 2, 4, 4, 2, 8
    pool = jnp.zeros((L, NB, bs, K, hd), jnp.float32)
    rows = jax.random.normal(jax.random.PRNGKey(1), (L, 3, K, hd))
    pool = scatter_pool_rows(pool, [2, 2, 1], [1, 2, 0], rows)
    np.testing.assert_array_equal(np.asarray(pool[:, 2, 1]),
                                  np.asarray(rows[:, 0]))
    np.testing.assert_array_equal(np.asarray(pool[:, 2, 2]),
                                  np.asarray(rows[:, 1]))
    np.testing.assert_array_equal(np.asarray(pool[:, 1, 0]),
                                  np.asarray(rows[:, 2]))
    assert float(jnp.abs(pool[:, 3]).sum()) == 0.0


def test_prefix_tables_masks_unwritten_tail():
    pool = RankKVPool(8, 4)
    pool.append_tokens(1, 20)                     # 5 blocks reserved
    tables, tails = prefix_tables([pool], 1, [10], 8)
    assert tables.shape == (1, 1, 8)
    # Coverage 10 = 2 full blocks + 2 tokens of the third.
    np.testing.assert_array_equal(tables[0, 0, :3],
                                  pool.requests[1].blocks[:3])
    assert (tables[0, 0, 3:] == -1).all() and tails[0, 0] == 2
    # Zero coverage => empty table (identity partial).
    t0, _ = prefix_tables([pool], 1, [0], 8)
    assert (t0 == -1).all()


# ------------------------------------------------------------------ #
# prefill_chunk_paged == dense prefill() oracle (logits + pool rows)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch", ["olmo-1b", "kimi-k2-1t-a32b"])
@pytest.mark.parametrize("chunk", [5, 8, 32])
def test_chunked_prefill_matches_dense_oracle(arch, chunk):
    cfg, params = _setup(arch)
    rng = np.random.default_rng(0)
    T, NB, bs = 22, 16, 4
    prompt = rng.integers(0, cfg.vocab_size, T).tolist()
    logits_ref, full = prefill(params, cfg, jnp.asarray([prompt], jnp.int32),
                               max_len=T)
    L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.dtype)
    pool_k = jnp.zeros((L, NB, bs, K, hd), dt)
    pool_v = jnp.zeros((L, NB, bs, K, hd), dt)
    pool = RankKVPool(NB, bs)
    pool.append_tokens(0, T)
    blocks = pool.requests[0].blocks
    logits = None
    for t0 in range(0, T, chunk):
        t1 = min(t0 + chunk, T)
        n_valid = t1 - t0
        toks = np.zeros(chunk, np.int32)
        toks[:n_valid] = prompt[t0:t1]
        wblk = np.full(chunk, NB, np.int32)
        woff = np.zeros(chunk, np.int32)
        blk, off = rows_for_token_range(blocks, bs, t0, t1)
        wblk[:n_valid] = blk
        woff[:n_valid] = off
        tables, tails = prefix_tables([pool], 0, [t0],
                                      table_bucket(max(1, -(-t0 // bs))))
        logits, pool_k, pool_v, _, _ = prefill_chunk_paged(
            params, cfg, toks, t0, n_valid, pool_k, pool_v,
            tables, tails, wblk, woff)
    np.testing.assert_allclose(np.asarray(logits[0], np.float32),
                               np.asarray(logits_ref[0], np.float32),
                               atol=5e-2, rtol=5e-2)
    got_k = read_pool_rows(pool_k, blocks, bs)[:, :T]
    got_v = read_pool_rows(pool_v, blocks, bs)[:, :T]
    np.testing.assert_allclose(np.asarray(got_k, np.float32),
                               np.asarray(full.kv_k[:, 0], np.float32),
                               atol=4e-2, rtol=5e-2)
    np.testing.assert_allclose(np.asarray(got_v, np.float32),
                               np.asarray(full.kv_v[:, 0], np.float32),
                               atol=4e-2, rtol=5e-2)


# ------------------------------------------------------------------ #
# The serving admission path never runs the dense prefill
# ------------------------------------------------------------------ #
def test_streaming_admission_avoids_dense_prefill(monkeypatch):
    cfg, params = _setup("olmo-1b")
    rng = np.random.default_rng(1)
    prompt = list(rng.integers(0, cfg.vocab_size, 13))
    n_new = 6
    ref = _greedy_reference(params, cfg, prompt, n_new)

    def boom(*a, **k):
        raise AssertionError("dense prefill() on the pooled admission path")
    monkeypatch.setattr(engine_mod, "prefill", boom)

    eng = InstanceEngine(params, cfg, max_batch=2, max_local_len=64,
                         pool_blocks=32, block_size=8, prefill_chunk=8)
    req = Request(prompt=prompt, sampling=SamplingParams(max_new_tokens=n_new))
    eng.submit(req)
    for _ in range(20):
        if req.done:
            break
        eng.step()
    assert req.state == RequestState.FINISHED
    assert req.output == ref


# ------------------------------------------------------------------ #
# Prefix striped over >= 2 creditors at admission; decode exact
# ------------------------------------------------------------------ #
def test_prefix_stripes_across_two_creditors_and_decodes():
    cfg, params = _setup("olmo-1b")
    rng = np.random.default_rng(2)
    T, n_new = 40, 8
    prompt = list(rng.integers(0, cfg.vocab_size, T))
    ref = _greedy_reference(params, cfg, prompt, n_new)

    # Owner quota 16 (bs=4) => 28-token prefix = 7 blocks, but each
    # creditor pool only has 6 blocks: admission must stripe across 2.
    cl = Cluster(params, cfg, ServingConfig.smoke(
        n_instances=3, max_batch=2, max_local_len=16, pool_blocks=6,
        block_size=4))
    req = Request(prompt=prompt, sampling=SamplingParams(max_new_tokens=n_new))
    cl.submit(req)
    traces_before = prefill_mod.prefill_chunk_trace_count()
    cl.step()
    # 5 chunks stream through ONE fixed-shape compile: table buckets and
    # rank count are constant across the whole admission.
    traces = prefill_mod.prefill_chunk_trace_count() - traces_before
    assert 1 <= traces <= 2, f"chunk step retraced {traces}x in one admit"
    owner = next(e for e in cl.engines.values()
                 if req.req_id in e.remote_insts)
    assert len(owner.remote_insts[req.req_id]) >= 2, \
        "prefix did not stripe across multiple creditors"
    # Admission stages O(chunk) prompt KV, not O(T): the largest staged
    # array is one chunk's [L, C, K, hd] export, never a dense cache.
    L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    itemsize = jnp.dtype(cfg.dtype).itemsize
    chunk_bytes = 2 * L * 8 * K * hd * itemsize
    dense_bytes = 2 * L * T * K * hd * itemsize
    assert 0 < owner.stats.admit_stage_bytes <= chunk_bytes < dense_bytes

    cl.run_until_done(max_steps=200)
    assert req.state == RequestState.FINISHED
    assert req.output == ref, "striped streaming admission diverged"


def test_cluster_oom_prefix_fails_cleanly():
    """No creditor capacity at all: admission fails BEFORE any compute
    and every reservation is rolled back."""
    cfg, params = _setup("olmo-1b")
    rng = np.random.default_rng(3)
    cl = Cluster(params, cfg, ServingConfig.smoke(
        n_instances=1, max_batch=2, max_local_len=16, pool_blocks=8,
        block_size=4))
    req = Request(prompt=list(rng.integers(0, cfg.vocab_size, 40)),
                  sampling=SamplingParams(max_new_tokens=4))
    cl.submit(req)
    cl.step()
    assert req.state == RequestState.FAILED
    eng = cl.engines[0]
    assert eng.rmanager.pool.alloc.used_count == 0
    assert eng.rmanager.pool.alloc.reserved == 0
