"""Serving stack integration: engine, cluster DistAttention spanning,
KV movement, fault tolerance, elasticity, and the LLMServer frontend
(submit -> stream -> cancel, with allocator state verified clean)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import decode_step, init_params
from repro.models.prefill import prefill
from repro.serving import (Cluster, InstanceEngine, LLMServer, Request,
                           RequestState, SamplingParams, ServingConfig)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_reference(params, cfg, prompt, n_new):
    """Naive reference generation: prefill + plain decode, greedy."""
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
    return out


def test_engine_matches_reference(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (5, 9, 13)]
    n_new = 6
    refs = [_greedy_reference(params, cfg, p, n_new) for p in prompts]

    eng = InstanceEngine(params, cfg, max_batch=4, max_local_len=64,
                         pool_blocks=64, block_size=8)
    reqs = [Request(prompt=p, sampling=SamplingParams(max_new_tokens=n_new))
            for p in prompts]
    for r in reqs:
        eng.submit(r)
    for _ in range(50):
        if all(r.done for r in reqs):
            break
        eng.step()
    for r, ref in zip(reqs, refs):
        assert r.state == RequestState.FINISHED
        assert r.output == ref, f"continuous batching diverged: " \
                                f"{r.output} vs {ref}"


def test_cluster_spanning_request_matches_reference(setup):
    """A request whose KV overflows its instance must produce EXACTLY the
    same greedy tokens via DistAttention spanning — the paper's core
    serving-correctness claim."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    long_prompt = list(rng.integers(0, cfg.vocab_size, size=40))
    n_new = 24                                  # forces mid-decode moves
    ref = _greedy_reference(params, cfg, long_prompt, n_new)

    # max_local_len=32 < 40-token prompt: spills at prefill AND moves
    # reactively during decode.
    cl = Cluster(params, cfg, ServingConfig.smoke(
        n_instances=3, max_batch=2, pool_blocks=32))
    req = Request(prompt=long_prompt,
                  sampling=SamplingParams(max_new_tokens=n_new))
    cl.submit(req)
    cl.run_until_done(max_steps=200)
    assert req.state == RequestState.FINISHED
    assert req.output == ref, "DistAttention spanning diverged from " \
                              "single-cache reference"
    stats = cl.throughput_stats
    assert stats["kv_moved_bytes"] > 0          # KV really moved
    assert stats["query_shipped_bytes"] > 0     # merge traffic charged


def test_cluster_mixed_load_all_finish(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    reqs = []
    for n in (4, 6, 45, 8, 10):
        reqs.append(Request(prompt=list(rng.integers(0, cfg.vocab_size,
                                                     size=n)),
                            sampling=SamplingParams(max_new_tokens=8)))
    cl = Cluster(params, cfg, ServingConfig.smoke(move_chunk_tokens=16))
    for r in reqs:
        cl.submit(r)
    cl.run_until_done(max_steps=300)
    assert all(r.state == RequestState.FINISHED for r in reqs)


def test_cluster_instance_failure_recovers(setup):
    """Kill the owner mid-generation: request token-replays on survivors and
    produces a greedy output byte-identical to the unfailed oracle."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompt = list(rng.integers(0, cfg.vocab_size, size=10))
    n_new = 10
    ref = _greedy_reference(params, cfg, prompt, n_new)

    cl = Cluster(params, cfg, ServingConfig.smoke(
        max_batch=2, max_local_len=64, pool_blocks=32,
        heartbeat_timeout=0.0))
    req = Request(prompt=prompt, sampling=SamplingParams(max_new_tokens=n_new))
    cl.submit(req)
    for _ in range(4):
        cl.step()
    owner = next(i for i, e in cl.engines.items() if req in e.running)
    cl.kill_instance(owner)
    cl.run_until_done(max_steps=200)
    assert req.state == RequestState.FINISHED
    # Token replay keeps the prompt intact and re-emits nothing: the
    # output stream must be byte-identical to the unfailed reference.
    assert req.prompt == prompt
    assert req.output == ref
    assert req.replays == 1
    assert cl.fault_stats.recoveries == 1


# ------------------------------------------------------------------ #
# LLMServer frontend: submit -> stream -> cancel end to end
# ------------------------------------------------------------------ #
def _pools_clean(cluster, req_id):
    """No engine holds blocks or reservations for req_id."""
    for eng in cluster.engines.values():
        if req_id in eng.rmanager.pool.requests:
            return False
        if eng.rmanager.pool.alloc.reserved != 0:
            return False
    return True


def test_server_submit_stream_cancel_end_to_end(setup):
    """The acceptance flow: submit through LLMServer, stream tokens
    incrementally off the engine's emit path, cancel mid-generation,
    and verify the pool allocators are clean after the cancellation
    while the surviving request still matches the greedy oracle."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    keep_prompt = list(rng.integers(0, cfg.vocab_size, size=7))
    n_new = 10
    ref = _greedy_reference(params, cfg, keep_prompt, n_new)

    server = LLMServer(params, cfg, ServingConfig.smoke(max_batch=2))
    keep = server.submit(keep_prompt, SamplingParams(max_new_tokens=n_new))
    victim = server.submit(list(rng.integers(0, cfg.vocab_size, size=9)),
                           SamplingParams(max_new_tokens=64),
                           priority=1, deadline_s=60.0)

    streamed = []
    for tok in keep.tokens():
        streamed.append(tok)
        if len(victim._req.output) >= 3 and not victim.done:
            assert victim.status == RequestState.RUNNING
            assert victim.cancel()
            # Terminal immediately; engine slot + local blocks released.
            assert victim.status == RequestState.CANCELLED
            assert _pools_clean(server.cluster, victim.req_id)
    assert streamed == ref, "streamed tokens diverged from the oracle"
    assert keep.result() == ref
    assert keep.status == RequestState.FINISHED
    assert victim.status == RequestState.CANCELLED
    assert 3 <= len(victim._req.output) < 64
    # Cancel of a terminal request is a no-op.
    assert not victim.cancel()
    assert _pools_clean(server.cluster, victim.req_id)
    # Per-request lifecycle metrics are real (satellite: arrival/finish).
    for h in (keep, victim):
        m = h.metrics
        assert m["arrival_time"] > 0.0 and m["finish_time"] >= \
            m["arrival_time"]
        assert m["ttft"] >= 0.0 and m["e2e"] >= m["ttft"]
    assert keep.metrics["n_tokens"] == n_new


def test_server_ids_are_per_server_and_deterministic(setup):
    """Two servers in one process get independent dense id spaces
    (module-global counter drift is gone); bare Request() still works."""
    cfg, params = setup
    s1 = LLMServer(params, cfg, ServingConfig.smoke(n_instances=1))
    s2 = LLMServer(params, cfg, ServingConfig.smoke(n_instances=1))
    h1 = [s1.submit([1, 2, 3]), s1.submit([4, 5])]
    h2 = [s2.submit([6]), s2.submit([7, 8])]
    assert [h.req_id for h in h1] == [0, 1]
    assert [h.req_id for h in h2] == [0, 1]
    r = Request(prompt=[1])             # standalone construction survives
    assert isinstance(r.req_id, int)


def test_server_backpressure_reject_policy(setup):
    cfg, params = setup
    server = LLMServer(params, cfg, ServingConfig.smoke(
        n_instances=1, max_batch=2, max_waiting=2,
        admission_policy="reject"))
    handles = [server.submit([1, 2, 3], SamplingParams(max_new_tokens=2))
               for _ in range(5)]
    rejected = [h for h in handles if h.status == RequestState.FAILED]
    assert len(rejected) == 3 and server.rejected == 3
    server.drain()
    accepted = [h for h in handles if h not in rejected]
    assert all(h.status == RequestState.FINISHED for h in accepted)


def test_server_open_loop_run_records_latency_metrics(setup):
    cfg, params = setup
    from repro.serving import Arrival
    rng = np.random.default_rng(8)
    arrivals = [Arrival(at=0.02 * i,
                        prompt=list(rng.integers(0, cfg.vocab_size, 5)),
                        sampling=SamplingParams(max_new_tokens=4))
                for i in range(4)]
    server = LLMServer(params, cfg, ServingConfig.smoke(n_instances=1,
                                                        max_batch=2))
    stats = server.run(arrivals)
    assert stats["finished"] == 4 and stats["tokens"] == 16
    assert stats["ttft_p50"] > 0.0 and stats["ttft_p99"] >= \
        stats["ttft_p50"]
    assert stats["tbt_p99"] > 0.0
    assert stats["deadline_missed"] == 0


def test_cluster_elastic_scale_out(setup):
    cfg, params = setup
    rng = np.random.default_rng(4)
    cl = Cluster(params, cfg, ServingConfig.smoke(
        n_instances=1, max_batch=2, pool_blocks=16))
    # Too long for one instance's pool: needs the new creditor.
    req = Request(prompt=list(rng.integers(0, cfg.vocab_size, size=30)),
                  sampling=SamplingParams(max_new_tokens=16))
    cl.add_instance(params)
    cl.submit(req)
    cl.run_until_done(max_steps=200)
    assert req.state == RequestState.FINISHED
