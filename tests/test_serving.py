"""Serving stack integration: engine, cluster DistAttention spanning,
KV movement, fault tolerance, elasticity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import decode_step, init_params
from repro.models.prefill import prefill
from repro.serving import (Cluster, InstanceEngine, Request, RequestState,
                           SamplingParams)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_reference(params, cfg, prompt, n_new):
    """Naive reference generation: prefill + plain decode, greedy."""
    tokens = jnp.asarray([prompt], jnp.int32)
    logits, state = prefill(params, cfg, tokens,
                            max_len=len(prompt) + n_new + 2)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([out[-1]], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
    return out


def test_engine_matches_reference(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (5, 9, 13)]
    n_new = 6
    refs = [_greedy_reference(params, cfg, p, n_new) for p in prompts]

    eng = InstanceEngine(params, cfg, max_batch=4, max_local_len=64,
                         pool_blocks=64, block_size=8)
    reqs = [Request(prompt=p, sampling=SamplingParams(max_new_tokens=n_new))
            for p in prompts]
    for r in reqs:
        eng.submit(r)
    for _ in range(50):
        if all(r.done for r in reqs):
            break
        eng.step()
    for r, ref in zip(reqs, refs):
        assert r.state == RequestState.FINISHED
        assert r.output == ref, f"continuous batching diverged: " \
                                f"{r.output} vs {ref}"


def test_cluster_spanning_request_matches_reference(setup):
    """A request whose KV overflows its instance must produce EXACTLY the
    same greedy tokens via DistAttention spanning — the paper's core
    serving-correctness claim."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    long_prompt = list(rng.integers(0, cfg.vocab_size, size=40))
    n_new = 24                                  # forces mid-decode moves
    ref = _greedy_reference(params, cfg, long_prompt, n_new)

    # max_local_len=32 < 40-token prompt: spills at prefill AND moves
    # reactively during decode.
    cl = Cluster(params, cfg, n_instances=3, max_batch=2, max_local_len=32,
                 pool_blocks=32, block_size=8, move_chunk_tokens=8)
    req = Request(prompt=long_prompt,
                  sampling=SamplingParams(max_new_tokens=n_new))
    cl.submit(req)
    cl.run_until_done(max_steps=200)
    assert req.state == RequestState.FINISHED
    assert req.output == ref, "DistAttention spanning diverged from " \
                              "single-cache reference"
    stats = cl.throughput_stats
    assert stats["kv_moved_bytes"] > 0          # KV really moved
    assert stats["query_shipped_bytes"] > 0     # merge traffic charged


def test_cluster_mixed_load_all_finish(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    reqs = []
    for n in (4, 6, 45, 8, 10):
        reqs.append(Request(prompt=list(rng.integers(0, cfg.vocab_size,
                                                     size=n)),
                            sampling=SamplingParams(max_new_tokens=8)))
    cl = Cluster(params, cfg, n_instances=2, max_batch=3, max_local_len=32,
                 pool_blocks=48, block_size=8)
    for r in reqs:
        cl.submit(r)
    cl.run_until_done(max_steps=300)
    assert all(r.state == RequestState.FINISHED for r in reqs)


def test_cluster_instance_failure_recovers(setup):
    """Kill the owner mid-generation: request re-prefills on survivors and
    produces the same greedy output."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompt = list(rng.integers(0, cfg.vocab_size, size=10))
    n_new = 10
    ref = _greedy_reference(params, cfg, prompt, n_new)

    cl = Cluster(params, cfg, n_instances=2, max_batch=2, max_local_len=64,
                 pool_blocks=32, block_size=8, heartbeat_timeout=0.0)
    req = Request(prompt=prompt, sampling=SamplingParams(max_new_tokens=n_new))
    cl.submit(req)
    for _ in range(4):
        cl.step()
    owner = next(i for i, e in cl.engines.items() if req in e.running)
    cl.kill_instance(owner)
    cl.run_until_done(max_steps=200)
    assert req.state == RequestState.FINISHED
    # Re-prefill restarts generation from prompt+partial outputs, so the
    # final prefix must match the reference stream.
    joined = req.prompt[len(prompt):] + req.output
    assert joined[:n_new] == ref[:len(joined[:n_new])]
    assert len(joined) >= n_new


def test_cluster_elastic_scale_out(setup):
    cfg, params = setup
    rng = np.random.default_rng(4)
    cl = Cluster(params, cfg, n_instances=1, max_batch=2, max_local_len=32,
                 pool_blocks=16, block_size=8)
    # Too long for one instance's pool: needs the new creditor.
    req = Request(prompt=list(rng.integers(0, cfg.vocab_size, size=30)),
                  sampling=SamplingParams(max_new_tokens=16))
    cl.add_instance(params)
    cl.submit(req)
    cl.run_until_done(max_steps=200)
    assert req.state == RequestState.FINISHED
