"""Training stack: optimizer, loss descent, microbatching equivalence,
grad compression EF, data determinism, checkpoint/restart fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, restore_train_state, \
    save_train_state
from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.training.compression import (compress_grads_with_ef,
                                        decompress_grads,
                                        init_error_feedback)
from repro.training.data import DataConfig, batch_for_step
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import (TrainConfig, init_train_state,
                                       lm_loss, train_step)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _batch(cfg, B=4, S=16, seed=0):
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B,
                    seed=seed)
    toks, mask = batch_for_step(dc, 0)
    return jnp.asarray(toks), jnp.asarray(mask)


def test_loss_decreases(setup):
    cfg, params = setup
    tcfg = TrainConfig(remat=False, microbatches=1)
    acfg = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=100)
    state = init_train_state(params, acfg, tcfg)
    toks, mask = _batch(cfg)
    step = jax.jit(lambda s, t, m: train_step(
        s, t, m, cfg=cfg, tcfg=tcfg, adam_cfg=acfg))
    losses = []
    for _ in range(8):
        state, out = step(state, toks, mask)
        losses.append(float(out["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_remat_same_loss_and_grads(setup):
    cfg, params = setup
    toks, mask = _batch(cfg)
    l1, _ = lm_loss(params, cfg, toks, mask, TrainConfig(remat=False))
    l2, _ = lm_loss(params, cfg, toks, mask, TrainConfig(remat=True))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda p: lm_loss(p, cfg, toks, mask,
                                    TrainConfig(remat=False))[0])(params)
    g2 = jax.grad(lambda p: lm_loss(p, cfg, toks, mask,
                                    TrainConfig(remat=True))[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-3)


def test_microbatch_accumulation_matches_full_batch(setup):
    cfg, params = setup
    toks, mask = _batch(cfg, B=4)
    acfg = AdamWConfig(lr=1e-3, warmup_steps=1)
    s_full = init_train_state(params, acfg, TrainConfig(remat=False))
    s_micro = init_train_state(params, acfg, TrainConfig(remat=False,
                                                         microbatches=2))
    s1, o1 = train_step(s_full, toks, mask, cfg=cfg,
                        tcfg=TrainConfig(remat=False), adam_cfg=acfg)
    s2, o2 = train_step(s_micro, toks, mask, cfg=cfg,
                        tcfg=TrainConfig(remat=False, microbatches=2),
                        adam_cfg=acfg)
    # Loss normalization differs (per-microbatch token counts), but the
    # parameters should move almost identically for uniform masks.
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-2)


def test_grad_compression_error_feedback_unbiased():
    """EF: the residual carries over so sum of dequantized grads over
    steps tracks the true sum (no systematic bias)."""
    key = jax.random.PRNGKey(0)
    g_true = {"w": jax.random.normal(key, (64, 64)) * 1e-3}
    ef = init_error_feedback(g_true)
    acc = jnp.zeros((64, 64))
    for i in range(20):
        q, ef = compress_grads_with_ef(g_true, ef)
        acc = acc + decompress_grads(q)["w"]
    err = float(jnp.max(jnp.abs(acc - 20 * g_true["w"])))
    scale = float(jnp.max(jnp.abs(g_true["w"])))
    assert err < scale, "error feedback failed to bound drift"


def test_data_pipeline_deterministic_and_restartable():
    dc = DataConfig(vocab_size=100, seq_len=32, global_batch=2, seed=7)
    t1, m1 = batch_for_step(dc, 5)
    t2, m2 = batch_for_step(dc, 5)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(m1, m2)
    t3, _ = batch_for_step(dc, 6)
    assert not np.array_equal(t1, t3)


def test_checkpoint_restart_identical_training(tmp_path, setup):
    """Kill-and-restore mid-run: the restarted run reproduces the original
    trajectory exactly (deterministic pipeline + restored state)."""
    cfg, params = setup
    tcfg = TrainConfig(remat=False)
    acfg = AdamWConfig(lr=1e-3, warmup_steps=2)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2,
                    seed=1)
    ckpt = Checkpointer(str(tmp_path), keep=2)

    def run(state, start, n, save_at=None):
        for s in range(start, start + n):
            toks, mask = batch_for_step(dc, s)
            state, out = train_step(state, jnp.asarray(toks),
                                    jnp.asarray(mask), cfg=cfg, tcfg=tcfg,
                                    adam_cfg=acfg)
            if save_at is not None and s == save_at:
                save_train_state(ckpt, s, state)
        return state, out

    state0 = init_train_state(params, acfg, tcfg)
    final, out_a = run(state0, 0, 6, save_at=2)

    # "Crash" after step 2; restore and replay steps 3..5.
    step = ckpt.latest()
    assert step == 2
    like = init_train_state(params, acfg, tcfg)
    restored = restore_train_state(ckpt, step, like)
    refinal, out_b = run(restored, 3, 3)
    np.testing.assert_allclose(float(out_a["loss"]), float(out_b["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(final.params),
                    jax.tree.leaves(refinal.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_checkpoint_atomic_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.ones((4,)), "b": {"c": jnp.zeros((2, 2))}}
    for s in (1, 2, 3):
        ck.save(s, tree)
    assert ck.steps() == [2, 3]                 # GC kept last 2
    # Simulate crash: stale .tmp dir must be ignored + reaped.
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert ck.latest() == 3
    ck.save(4, tree)
    assert not any(n.endswith(".tmp") for n in os.listdir(str(tmp_path)))
