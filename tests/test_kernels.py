"""Pallas kernels vs ref.py oracles: shape/dtype sweeps, allclose."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import flash_prefill, paged_micro_attention
from repro.core.online_softmax import micro_attention_decode


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("B,S,H,K,D", [
    (1, 128, 4, 4, 16),      # MHA
    (2, 256, 8, 2, 32),      # GQA
    (1, 200, 4, 1, 112),     # MQA, ragged seq, unaligned head dim
    (1, 64, 3, 3, 8),        # odd head count
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_matches_ref(B, S, H, K, D, dtype):
    key = jax.random.PRNGKey(42)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (B, S, H, D), dtype)
    k = _rand(kk, (B, S, K, D), dtype)
    v = _rand(kv, (B, S, K, D), dtype)
    got = flash_prefill(q, k, v, bq=64, bk=64, interpret=True)
    want = ref.flash_prefill_ref(q, k, v)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_prefill_sliding_window(window):
    key = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(key, 3)
    B, S, H, K, D = 1, 128, 4, 2, 16
    q = _rand(kq, (B, S, H, D), jnp.float32)
    k = _rand(kk, (B, S, K, D), jnp.float32)
    v = _rand(kv, (B, S, K, D), jnp.float32)
    got = flash_prefill(q, k, v, window=window, bq=32, bk=32, interpret=True)
    want = ref.flash_prefill_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def _make_pool(key, R, NB, bs, K, D, MB, dtype, rng):
    """Random pool + tables with variable block counts and tail lengths."""
    kk, kv = jax.random.split(key)
    pool_k = _rand(kk, (NB, bs, K, D), dtype)
    pool_v = _rand(kv, (NB, bs, K, D), dtype)
    table = -np.ones((R, MB), np.int32)
    nblk = rng.integers(0, MB + 1, size=R)
    tail = np.ones((R,), np.int32)
    perm = rng.permutation(NB)
    used = 0
    for r in range(R):
        n = int(nblk[r])
        take = perm[used:used + n]
        if len(take) < n:          # pool exhausted; shrink
            n = len(take)
            nblk[r] = n
        table[r, :n] = take
        used += n
        tail[r] = rng.integers(1, bs + 1) if n else bs
    return pool_k, pool_v, jnp.asarray(table), jnp.asarray(nblk, jnp.int32), \
        jnp.asarray(tail)


@pytest.mark.parametrize("R,NB,bs,K,G,D,MB", [
    (4, 16, 16, 2, 2, 16, 4),
    (3, 32, 8, 1, 4, 32, 8),      # MQA
    (2, 8, 32, 4, 1, 112, 3),     # MHA, unaligned head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_micro_attention_matches_ref(R, NB, bs, K, G, D, MB, dtype):
    H = K * G
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(3)
    kq, kp = jax.random.split(key)
    q = _rand(kq, (R, H, D), dtype)
    pool_k, pool_v, table, nblk, tail = _make_pool(kp, R, NB, bs, K, D, MB,
                                                   dtype, rng)
    got_o, got_m, got_l = paged_micro_attention(q, pool_k, pool_v, table,
                                                tail, interpret=True)
    want_o, want_m, want_l = ref.paged_micro_attention_ref(
        q, pool_k, pool_v, table, nblk, tail)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(got_l), np.asarray(want_l),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o),
                               atol=tol, rtol=tol)


def test_paged_partial_merges_to_full_attention():
    """Kernel partials from two disjoint pools == full attention (Eq. 2+3)."""
    from repro.core.online_softmax import combine, finalize
    key = jax.random.PRNGKey(9)
    R, bs, K, G, D = 2, 8, 2, 2, 16
    H = K * G
    S = 64                                   # 8 blocks, split 5 / 3
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (R, H, D), jnp.float32)
    k = _rand(kk, (R, S, K, D), jnp.float32)
    v = _rand(kv, (R, S, K, D), jnp.float32)

    ref_out = finalize(*(lambda p: (p[0], p[2]))(
        micro_attention_decode(q, k, v, jnp.ones((R, S), bool))),
    ) if False else None
    from repro.core.attention import full_attention_decode
    ref_out = full_attention_decode(q, k, v, jnp.ones((R, S), bool))

    kb = k.reshape(R, 8, bs, K, D)
    vb = v.reshape(R, 8, bs, K, D)
    parts = []
    for blocks in (range(0, 5), range(5, 8)):
        idx = list(blocks)
        pool_k = kb[:, idx].reshape(-1, bs, K, D)
        pool_v = vb[:, idx].reshape(-1, bs, K, D)
        table = jnp.asarray(
            [[r * len(idx) + i for i in range(len(idx))] for r in range(R)],
            jnp.int32)
        tail = jnp.full((R,), bs, jnp.int32)
        parts.append(paged_micro_attention(q, pool_k, pool_v, table, tail,
                                           interpret=True))
    merged = combine(parts[0], parts[1])
    out = finalize(merged[0], merged[2])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=1e-4, rtol=1e-4)
